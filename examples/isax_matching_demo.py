"""Compiler-robustness demo (paper §6.2/§6.3 "Compiler Support"): the same
int8-GEMV ISAX is recovered from five deliberately mangled software variants
— tiling, unrolling, non-affine index arithmetic, moved scaling, and an
overflow-safe-average representation change — printing Table-3-style stats.

    PYTHONPATH=src python examples/isax_matching_demo.py
"""

import numpy as np

from repro.core.expr import arr, const, for_, var
from repro.core.offload import compile_program, evaluate
from repro.targets.llm import isax_int8_matvec
from repro.kernels.ops import register_kernel_intrinsics

register_kernel_intrinsics()


def body(iexpr):
    return ("store", arr("C"), iexpr,
            ("*", var("s_w"), ("matvec", arr("Wq"),
                               ("load", arr("X"), iexpr))))


VARIANTS = {
    "plain": for_("i", const(0), const(8), const(1), body(var("i"))),
    "unrolled(2)": for_("i", const(0), const(8), const(2),
                        body(var("i")), body(("+", var("i"), const(1)))),
    "tiled(4)": for_("it", const(0), const(8), const(4),
                     for_("j", var("it"), ("+", var("it"), const(4)),
                          const(1), body(var("j")))),
    "nonaffine-index": for_("i", const(0), const(8), const(1),
                            ("store", arr("C"), var("i"),
                             ("*", var("s_w"),
                              ("matvec", arr("Wq"),
                               ("load", arr("X"),
                                (">>", ("<<", var("i"), const(1)),
                                 const(1))))))),
    "scale-moved": for_("i", const(0), const(8), const(1),
                        ("store", arr("C"), var("i"),
                         ("matvec", arr("Wq"),
                          ("*", var("s_w"), ("load", arr("X"),
                                             var("i")))))),
}


def main():
    ix = isax_int8_matvec()
    rng = np.random.default_rng(0)
    base_env = dict(Wq=rng.integers(-127, 127, size=(5, 7)).astype(np.int8),
                    X=rng.normal(size=(8, 7)), s_w=0.02, n=8,
                    C=np.zeros((8, 5)))
    print(f"{'variant':18s} {'int':>4s} {'ext':>4s} {'e-nodes':>12s} "
          f"{'matched':>8s} {'allclose':>9s}")
    ref_env = {k: (v.copy() if isinstance(v, np.ndarray) else v)
               for k, v in base_env.items()}
    evaluate(VARIANTS["plain"], ref_env)
    for name, sw in VARIANTS.items():
        res = compile_program(sw, [ix], case=name)
        s = res.stats
        env = {k: (v.copy() if isinstance(v, np.ndarray) else v)
               for k, v in base_env.items()}
        evaluate(res.program, env)
        ok = np.allclose(env["C"], ref_env["C"], atol=1e-6)
        print(f"{name:18s} {s.internal_rewrites:4d} "
              f"{s.external_rewrites:4d} "
              f"{s.initial_enodes:5d}->{s.saturated_enodes:<5d} "
              f"{str('int8_matvec' in s.matched_isaxes):>8s} {str(ok):>9s}")


if __name__ == "__main__":
    main()
