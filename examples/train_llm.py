"""End-to-end training driver: the paper's §6.5 model (Llama-2-110M arch) on
synthetic data with checkpointing, straggler monitoring, and auto-resume.

Full run (a few hundred steps of the real 110M config — several CPU-hours):
    PYTHONPATH=src python examples/train_llm.py --steps 300

Smoke run (reduced config, ~1 min):
    PYTHONPATH=src python examples/train_llm.py --smoke --steps 30
"""

import argparse
import json
import os

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.optim.adamw import AdamWConfig
from repro.train.fault_tolerance import run_with_restarts
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama110m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="runs/train_llm")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    tc = TrainConfig(
        batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
        ckpt_every=25, total_steps=args.steps, warmup=min(20, args.steps // 5),
        optimizer=AdamWConfig(lr=3e-4, compress_grads=args.compress_grads))

    trainer = run_with_restarts(lambda: Trainer(cfg, tc), args.steps)
    log_path = os.path.join(args.ckpt_dir, "metrics.jsonl")
    os.makedirs(args.ckpt_dir, exist_ok=True)
    with open(log_path, "a") as f:
        for m in trainer.metrics_log:
            f.write(json.dumps(m) + "\n")
    first = trainer.metrics_log[0] if trainer.metrics_log else {}
    last = trainer.metrics_log[-1] if trainer.metrics_log else {}
    print(f"trained {args.arch}{' (reduced)' if args.smoke else ''} to step "
          f"{trainer.step}")
    print(f"loss {first.get('loss'):.4f} -> {last.get('loss'):.4f}; "
          f"stragglers flagged: {len(trainer.monitor.events)}")
    print(f"metrics: {log_path}; checkpoints: {args.ckpt_dir}")


if __name__ == "__main__":
    main()
