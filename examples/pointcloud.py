"""Point-cloud set abstraction through the full co-design stack.

The paper's second application domain, end to end:

1. Software side (§5): the divergently-spelled FPS / ball-query /
   group-aggregate loops e-graph-compile onto the point-cloud ISAXes
   (expanded squared distance → compact form, neg∘min∘neg → max-pool).
2. Hardware side (§4): the synthesis flow schedules the memory-bound
   gathers — streamed tile shapes plus the burst-DMA pipeline go/no-go.
3. System side: one PointNet++-style set-abstraction stage (sample →
   group → aggregate) runs through the compile-dispatch cache and matches
   the jnp references.

Run: PYTHONPATH=src python examples/pointcloud.py
"""

import numpy as np

from repro.compile import Dispatcher, LoweringConfig
from repro.compile.trace import trace_term
from repro.core.kernel_synth import choose_ball_blocks, choose_group_blocks
from repro.core.offload import compile_program, evaluate
from repro.targets import isax_library
from repro.pointcloud import ref
from repro.pointcloud.ops import register_pointcloud_intrinsics


def software_side():
    print("== 1. E-graph compilation of the point-cloud loops (§5) ==")
    register_pointcloud_intrinsics()
    for kind, want in (("fps", "fps"), ("ball_query", "ball_query"),
                       ("group_aggregate", "group_agg")):
        res = compile_program(trace_term(kind), isax_library(), case=kind)
        s = res.stats
        print(f"  {kind:16s} matched={s.matched_isaxes} "
              f"(int={s.internal_rewrites} rewrites, "
              f"e-nodes {s.initial_enodes} -> {s.saturated_enodes})")

    # offloaded fps program == reference program (numpy evaluator)
    rng = np.random.default_rng(0)
    n, n_s = 64, 8
    X = rng.normal(size=(n, 3))
    env = dict(Xp=X, n_s=n_s, Dp=np.full((1, n), 1e30),
               Sp=np.zeros(n_s, np.int64))
    env2 = {k: (v.copy() if isinstance(v, np.ndarray) else v)
            for k, v in env.items()}
    res = compile_program(trace_term("fps"), isax_library(), case="fps")
    evaluate(trace_term("fps"), env)
    evaluate(res.program, env2)
    print(f"  offloaded fps == original: "
          f"{bool((env['Sp'] == env2['Sp']).all())}\n")


def hardware_side():
    print("== 2. Synthesis schedules for the gather/scatter shapes (§4) ==")
    for label, sched in (
            ("ball_query 256c/4096pts/k16", choose_ball_blocks(256, 4096, 16)),
            ("group_agg 64c/4096pts/k8/c64", choose_group_blocks(64, 4096, 8, 64)),
            ("group_agg 512c/512pts/k64/c256 (compute-bound)",
             choose_group_blocks(512, 512, 64, 256))):
        print(f"  {label}: tiles={sched.block_shapes} "
              f"burst={sched.decisions['pipeline']}")
    print()


def system_side():
    print("== 3. Set abstraction through compile dispatch ==")
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    B, N, M, K, C = 1, 128, 32, 8, 16
    xyz = jnp.asarray(rng.normal(size=(B, N, 3)), jnp.float32)
    feats = jnp.asarray(rng.normal(size=(B, N, C)), jnp.float32)

    disp = Dispatcher()
    lw = LoweringConfig.from_registry("pallas_interpret", dispatcher=disp)
    sel = lw.fps(xyz, M)
    centers = jnp.take_along_axis(xyz, sel[..., None], axis=1)
    idx = lw.ball_query(xyz, centers, 1.2, K)
    agg = lw.group_aggregate(feats, idx)

    ok = (bool((np.asarray(sel) == np.asarray(ref.fps_ref(xyz, M))).all())
          and bool((np.asarray(idx)
                    == np.asarray(ref.ball_query_ref(xyz, centers, 1.2,
                                                     K))).all())
          and np.allclose(np.asarray(agg),
                          np.asarray(ref.group_aggregate_ref(feats, idx))))
    print(f"  sample({M}) -> group(k={K}) -> aggregate({C}ch): "
          f"parity={'OK' if ok else 'FAIL'}")
    for rec in disp.records.values():
        sched = rec.schedule or {}
        print(f"  {rec.key.op:16s} impl={rec.impl} "
              f"burst_pipeline={sched.get('pipelined', False)} "
              f"(gain={sched.get('pipeline_gain', 1.0):.2f}x)")
    assert ok, "point-cloud dispatch parity failed"


if __name__ == "__main__":
    software_side()
    hardware_side()
    system_side()
    print("\npointcloud example OK")
