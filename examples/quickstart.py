"""Quickstart: the full Aquas-on-TPU pipeline in one script.

1. Hardware side (§4): model the memory interfaces, synthesize a DMA
   schedule, derive Pallas kernel tile shapes.
2. Software side (§5): e-graph-compile a syntactically divergent attention
   loop onto the flash-attention ISAX and execute it.
3. System side: one train step + a short generation on a reduced model.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import aquas_ir as ir
from repro.core.expr import arr, const, for_, var
from repro.core.interface_model import paper_example_interfaces, tpu_interfaces
from repro.core.kernel_synth import choose_flash_blocks
from repro.core.offload import compile_program, evaluate
from repro.targets import isax_library
from repro.core.synthesis import synthesize
from repro.kernels.ops import register_kernel_intrinsics


def hardware_side():
    print("== 1. Interface-aware synthesis (paper §4) ==")
    t = synthesize(ir.FunctionalProgram("fir7", [
        ir.FuncOp("transfer", "src", 108, ir.Space.GLOBAL,
                  ir.Space.SCRATCHPAD, "load", ir.CacheHint.COLD),
        ir.FuncOp("transfer", "bias", 28, ir.Space.GLOBAL,
                  ir.Space.SCRATCHPAD, "load", ir.CacheHint.WARM,
                  scratchpad="bias")],
        {"bias": ir.ScratchpadDecl("bias", 28, ir.CacheHint.WARM,
                                   compute_cycles_per_elem=8.0)}),
        paper_example_interfaces())
    print(f"  fir7 schedule: {t.total_cycles:.0f} cycles; decisions:")
    for k, v in sorted(t.decisions.items()):
        print(f"    {k} = {v}")
    sched = choose_flash_blocks(4096, 4096, 128)
    print(f"  flash-attention tiles (synthesized for TPU): "
          f"{sched.block_shapes}, {sched.buffering}-deep buffering, "
          f"{sched.decisions['bound']}-bound")
    # compute-bound prefill: BlockSpec's implicit double buffering suffices;
    # memory-bound short-query/long-KV: explicit deep staging wins.
    for label, s in (("prefill 4k×4k", sched),
                     ("decode-ish 64×4k", choose_flash_blocks(64, 4096, 64))):
        print(f"  burst-DMA pipeline [{label}]: {s.decisions['pipeline']} "
              f"(est {s.est_serial_cycles:.0f} baseline → "
              f"{s.est_total_cycles:.0f} cycles)")
    print()


def software_side():
    print("== 2. E-graph retargetable compiler (paper §5) ==")
    register_kernel_intrinsics()
    i = var("i")
    q = ("load", arr("Q"), i)
    # deliberately divergent: scale inside matvec, no max-shift softmax
    s = ("/", ("exp", ("matvec", arr("K"), ("*", var("scale"), q))),
         ("rowsum", ("exp", ("matvec", arr("K"), ("*", var("scale"), q)))))
    sw = for_("i", const(0), var("n_q"), const(1),
              ("store", arr("P"), i, s),
              ("store", arr("O"), i,
               ("matvec", ("transpose", arr("V")), ("load", arr("P"), i))))
    res = compile_program(sw, isax_library(), case="quickstart")
    s = res.stats
    print(f"  matched ISAXs: {s.matched_isaxes}")
    print(f"  rewrites: {s.internal_rewrites} internal / "
          f"{s.external_rewrites} external; "
          f"e-nodes {s.initial_enodes} -> {s.saturated_enodes}")
    rng = np.random.default_rng(0)
    nq, nk, d = 8, 16, 32
    env = dict(Q=rng.normal(size=(nq, d)), K=rng.normal(size=(nk, d)),
               V=rng.normal(size=(nk, d)), scale=d ** -0.5, n_q=nq,
               P=np.zeros((nq, nk)), O=np.zeros((nq, d)))
    env2 = {k: (v.copy() if isinstance(v, np.ndarray) else v)
            for k, v in env.items()}
    evaluate(sw, env)
    evaluate(res.program, env2)
    print(f"  offloaded == original: "
          f"{np.allclose(env['O'], env2['O'], atol=1e-6)}\n")


def system_side():
    print("== 3. Train + serve (reduced llama110m) ==")
    import jax.numpy as jnp
    from repro.configs.base import reduced
    from repro.configs.registry import get_config
    from repro.optim.adamw import AdamWConfig
    from repro.serve.engine import ServeEngine
    from repro.train.trainer import TrainConfig, Trainer

    cfg = reduced(get_config("llama110m"))
    tr = Trainer(cfg, TrainConfig(batch=4, seq=32, total_steps=5,
                                  optimizer=AdamWConfig(lr=1e-3)))
    last = tr.train(5)
    print(f"  5 train steps, loss: "
          f"{tr.metrics_log[0]['loss']:.3f} -> {last['loss']:.3f}")
    eng = ServeEngine(cfg, params=tr.params, max_len=48, quantize=True)
    toks, stats = eng.generate({"tokens": jnp.ones((2, 8), jnp.int32)}, 6)
    print(f"  generated {toks.shape} tokens, "
          f"TTFT {stats.ttft_s * 1e3:.1f} ms, ITL {stats.itl_s * 1e3:.1f} ms")


if __name__ == "__main__":
    hardware_side()
    software_side()
    system_side()
    print("\nquickstart OK")
