"""Batched serving example (paper §6.5): prefill + decode with KV cache,
TTFT/ITL measurement, int8 weight quantization, resuming weights from the
train_llm checkpoint when present — then the same traffic served by the
continuous-batching engine (paged KV cache, rolling admissions).

    PYTHONPATH=src python examples/serve_llm.py --smoke --tokens 16
"""

import argparse

import jax
import numpy as np

from repro.compile import VALID_BACKENDS, LoweringConfig
from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.serve.engine import ContinuousEngine, ServeEngine
from repro.serve.scheduler import Request
from repro.train import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama110m")
    ap.add_argument("--backend", default=None, choices=VALID_BACKENDS,
                    help="kernel lowering backend (default: "
                         "REPRO_ATTENTION_IMPL env or 'xla')")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--int8", action="store_true",
                    help="serve the continuous-batching section with int8 "
                         "weights (the fp/int8 comparison above always runs "
                         "both)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    lowering = LoweringConfig.from_registry(backend=args.backend)
    params = None
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        tree, mf = ckpt.load(args.ckpt_dir)
        params = tree["params"]
        print(f"restored step-{mf['step']} weights from {args.ckpt_dir}")
    max_len = args.prompt_len + args.tokens + 8
    prompts = jax.random.randint(jax.random.key(0),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)

    for mode, quant in (("fp", False), ("int8", True)):
        eng = ServeEngine(cfg, params=params, max_len=max_len,
                          quantize=quant, lowering=lowering)
        toks, stats = eng.generate({"tokens": prompts}, args.tokens)
        print(f"[{mode:5s}] TTFT {stats.ttft_s * 1e3:8.1f} ms | "
              f"ITL {stats.itl_s * 1e3:7.2f} ms | "
              f"{stats.tokens_per_s:7.1f} tok/s | out {toks.shape}")

    # Continuous batching: the same prompts arrive as individual requests
    # (staggered arrivals, mixed output lengths) and share decode slots
    # through the paged KV cache.
    if cfg.family not in ("dense", "moe"):
        print(f"[cont ] skipped: no paged decode path for {cfg.family}")
        return
    bucket = args.prompt_len + (-args.prompt_len) % 16
    cmax_len = max(128, max_len, bucket + args.tokens)
    cmax_len += (-cmax_len) % 16
    ceng = ContinuousEngine(cfg, params=params, max_batch=args.batch,
                            page_size=16, max_len=cmax_len,
                            prompt_buckets=(16, 32, 64, bucket),
                            quantize=args.int8, lowering=lowering)
    host_prompts = np.asarray(prompts, np.int32)
    reqs = [Request(rid=i, prompt=host_prompts[i],
                    max_new_tokens=max(2, args.tokens // (1 + i % 3)),
                    arrival_step=2 * i)
            for i in range(args.batch)]
    wstats = ceng.run(reqs)
    print(f"[cont ] TTFT {wstats.mean_ttft_s * 1e3:8.1f} ms | "
          f"ITL {wstats.mean_itl_s * 1e3:7.2f} ms | "
          f"{wstats.tokens_per_s:7.1f} tok/s | "
          f"{wstats.total_tokens} tokens in {wstats.decode_steps} steps")


if __name__ == "__main__":
    main()
