"""Batched serving example (paper §6.5): prefill + decode with KV cache,
TTFT/ITL measurement, int8 weight quantization, resuming weights from the
train_llm checkpoint when present.

    PYTHONPATH=src python examples/serve_llm.py --smoke --tokens 16
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.serve.engine import ServeEngine
from repro.train import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama110m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--int8", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    params = None
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        tree, mf = ckpt.load(args.ckpt_dir)
        params = tree["params"]
        print(f"restored step-{mf['step']} weights from {args.ckpt_dir}")
    max_len = args.prompt_len + args.tokens + 8
    prompts = jax.random.randint(jax.random.key(0),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)

    for mode, quant in (("fp", False), ("int8", True)):
        eng = ServeEngine(cfg, params=params, max_len=max_len,
                          quantize=quant)
        toks, stats = eng.generate({"tokens": prompts}, args.tokens)
        print(f"[{mode:5s}] TTFT {stats.ttft_s * 1e3:8.1f} ms | "
              f"ITL {stats.itl_s * 1e3:7.2f} ms | "
              f"{stats.tokens_per_s:7.1f} tok/s | out {toks.shape}")


if __name__ == "__main__":
    main()
