"""Figure 8 analog — edge LLM inference TTFT / ITL on the paper's own case
study model (Llama-2-110M architecture, int8 weights).

Baseline = fp32 engine; Aquas = int8-quantized weights (the paper's 8-bit
deployment; weight bytes at rest halve) — both measured on this CPU host.
Absolute times are CPU-host numbers; the paper's 9.3×/9.13× FPGA speedups
are RTL-vs-RTL and not reproducible here (see EXPERIMENTS.md)."""

from __future__ import annotations

import dataclasses
import os

import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.serve.engine import (ContinuousEngine, ServeEngine,
                                StaticBatchEngine, quantization_error,
                                quantize_params_int8)
from repro.serve.scheduler import Request, make_poisson_workload

# Per-scenario records for the BENCH_serve.json artifact; populated by run().
JSON_RECORDS: list[dict] = []


def _warmup(engine, buckets) -> None:
    """Trigger every prefill-bucket + decode compile outside the timed run."""
    import numpy as np
    warm = [Request(rid=-1 - i, prompt=np.ones((b,), np.int32),
                    max_new_tokens=2) for i, b in enumerate(buckets)]
    engine.run(warm)


def run_poisson_scenario(cfg, *, n_requests: int, max_batch: int,
                         max_len: int, seed: int = 0) -> list[dict]:
    """Static vs continuous batching on the identical mixed-length Poisson
    request stream (arrivals in decode-step virtual time); returns one
    record per engine with TTFT/ITL/tokens-per-s."""
    buckets = (16, 32)
    # Output lengths are heavy-tailed (a few long generations among many
    # short ones), the regime real LLM traffic lives in and where static
    # batching stalls whole groups on the longest member.
    mk = lambda: make_poisson_workload(
        n_requests, rate=4.0, vocab=cfg.vocab,
        prompt_lens=(8, 16, 24, 32), out_lens=(4, 8, 16, 48), seed=seed)
    engines = {
        "static": StaticBatchEngine(cfg, batch=max_batch, max_len=max_len,
                                    prompt_buckets=buckets, seed=0),
        "continuous": ContinuousEngine(cfg, max_batch=max_batch,
                                       page_size=16, max_len=max_len,
                                       prompt_buckets=buckets, seed=0),
    }
    records = []
    for name, eng in engines.items():
        _warmup(eng, buckets)
        eng.run(mk())        # full warm run (allocator + dispatch paths)
        # Best of two measured runs: this host is a shared CPU and a single
        # run can absorb transient interference.
        stats = max((eng.run(mk()) for _ in range(2)),
                    key=lambda s: s.tokens_per_s)
        records.append({
            "scenario": f"poisson_mixed/{name}",
            "n_requests": stats.n_requests,
            "total_tokens": stats.total_tokens,
            "ttft_s": stats.mean_ttft_s,
            "itl_s": stats.mean_itl_s,
            "tokens_per_s": stats.tokens_per_s,
            "decode_steps": stats.decode_steps,
        })
    engines["continuous"].cache.allocator.check_leaks()
    return records


def run() -> list[str]:
    rows = []
    smoke = os.environ.get("BENCH_SMOKE", "1") == "1"
    cfg = get_config("llama110m")
    if smoke:
        cfg = reduced(cfg)
    B, prompt_len, gen = (4, 32, 16) if smoke else (4, 128, 32)
    batch = {"tokens": jnp.ones((B, prompt_len), jnp.int32)}
    max_len = prompt_len + gen + 8

    eng = ServeEngine(cfg, max_len=max_len, seed=0)
    _, base = eng.generate(batch, gen)
    qtree, dequant = quantize_params_int8(eng.params)
    qerr = quantization_error(eng.params, qtree, dequant)
    engq = ServeEngine(cfg, params=eng.params, max_len=max_len,
                      quantize=True)
    _, aq = engq.generate(batch, gen)

    rows.append(f"serve/ttft_base,{base.ttft_s * 1e6:.0f},"
                f"batch={B};prompt={prompt_len}")
    rows.append(f"serve/ttft_int8,{aq.ttft_s * 1e6:.0f},"
                f"ratio={base.ttft_s / max(aq.ttft_s, 1e-9):.2f}x")
    rows.append(f"serve/itl_base,{base.itl_s * 1e6:.0f},"
                f"tok_per_s={base.tokens_per_s:.1f}")
    rows.append(f"serve/itl_int8,{aq.itl_s * 1e6:.0f},"
                f"tok_per_s={aq.tokens_per_s:.1f}")
    rows.append(f"serve/quant_err,{qerr * 1e6:.1f},rel_L1_x1e-6")

    # Throughput under load: static vs continuous batching on a Poisson
    # mixed-length stream (the tentpole's headline comparison).  The model
    # is sized so decode compute, not Python dispatch, dominates a step —
    # at reduced() scale the comparison measures interpreter overhead.
    n_req = 24 if smoke else 96
    serve_cfg = dataclasses.replace(reduced(get_config("llama110m")),
                                    n_layers=4, d_model=128, d_ff=256,
                                    head_dim=32)
    records = run_poisson_scenario(serve_cfg, n_requests=n_req,
                                   max_batch=8, max_len=128)
    JSON_RECORDS.clear()
    JSON_RECORDS.extend(records)
    by_name = {r["scenario"].split("/")[-1]: r for r in records}
    for name, r in by_name.items():
        rows.append(f"serve/poisson_{name},{r['itl_s'] * 1e6:.0f},"
                    f"ttft={r['ttft_s'] * 1e3:.1f}ms;"
                    f"tok_per_s={r['tokens_per_s']:.1f}")
    speedup = (by_name["continuous"]["tokens_per_s"]
               / max(by_name["static"]["tokens_per_s"], 1e-9))
    rows.append(f"serve/continuous_speedup,{speedup * 1e6:.0f},"
                f"{speedup:.2f}x_tokens_per_s")
    return rows
