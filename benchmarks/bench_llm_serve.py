"""Figure 8 analog — edge LLM inference TTFT / ITL on the paper's own case
study model (Llama-2-110M architecture, int8 weights).

Baseline = fp32 engine; Aquas = int8-quantized weights (the paper's 8-bit
deployment; weight bytes at rest halve) — both measured on this CPU host.
Absolute times are CPU-host numbers; the paper's 9.3×/9.13× FPGA speedups
are RTL-vs-RTL and not reproducible here (see EXPERIMENTS.md)."""

from __future__ import annotations

import os

import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.serve.engine import ServeEngine, quantization_error, \
    quantize_params_int8


def run() -> list[str]:
    rows = []
    smoke = os.environ.get("BENCH_SMOKE", "1") == "1"
    cfg = get_config("llama110m")
    if smoke:
        cfg = reduced(cfg)
    B, prompt_len, gen = (4, 32, 16) if smoke else (4, 128, 32)
    batch = {"tokens": jnp.ones((B, prompt_len), jnp.int32)}
    max_len = prompt_len + gen + 8

    eng = ServeEngine(cfg, max_len=max_len, seed=0)
    _, base = eng.generate(batch, gen)
    qtree, dequant = quantize_params_int8(eng.params)
    qerr = quantization_error(eng.params, qtree, dequant)
    engq = ServeEngine(cfg, params=eng.params, max_len=max_len,
                      quantize=True)
    _, aq = engq.generate(batch, gen)

    rows.append(f"serve/ttft_base,{base.ttft_s * 1e6:.0f},"
                f"batch={B};prompt={prompt_len}")
    rows.append(f"serve/ttft_int8,{aq.ttft_s * 1e6:.0f},"
                f"ratio={base.ttft_s / max(aq.ttft_s, 1e-9):.2f}x")
    rows.append(f"serve/itl_base,{base.itl_s * 1e6:.0f},"
                f"tok_per_s={base.tokens_per_s:.1f}")
    rows.append(f"serve/itl_int8,{aq.itl_s * 1e6:.0f},"
                f"tok_per_s={aq.tokens_per_s:.1f}")
    rows.append(f"serve/quant_err,{qerr * 1e6:.1f},rel_L1_x1e-6")
    return rows
