"""Table 3 analog — compilation statistics per case: control-flow difference,
internal/external rewrite counts, initial/saturated e-node counts, and
whether every pattern matched.  Mirrors the paper's robustness evaluation:
each case is a deliberately perturbed software variant."""

from __future__ import annotations

import time

import numpy as np

from repro.core.expr import arr, const, for_, var
from repro.core.offload import compile_program, isax_library


def _mv_body(iexpr):
    return ("store", arr("C"), iexpr,
            ("*", var("s_w"), ("matvec", arr("Wq"),
                               ("load", arr("X"), iexpr))))


def _cases():
    lib = {x.name: x for x in isax_library()}
    i = var("i")
    q = ("load", arr("Q"), i)
    s_noshift = ("/", ("exp", ("matvec", arr("K"), ("*", var("scale"), q))),
                 ("rowsum", ("exp", ("matvec", arr("K"),
                                     ("*", var("scale"), q)))))
    attn_variant = for_("i", const(0), var("n_q"), const(1),
                        ("store", arr("P"), i, s_noshift),
                        ("store", arr("O"), i,
                         ("matvec", ("transpose", arr("V")),
                          ("load", arr("P"), i))))
    unrolled = for_("i", const(0), const(8), const(2),
                    _mv_body(var("i")), _mv_body(("+", var("i"), const(1))))
    tiled = for_("it", const(0), const(8), const(4),
                 for_("j", var("it"), ("+", var("it"), const(4)), const(1),
                      _mv_body(var("j"))))
    shifted = for_("i", const(0), var("n"), const(1),
                   ("store", arr("C"), var("i"),
                    ("*", var("s_w"),
                     ("matvec", arr("Wq"),
                      ("load", arr("X"), (">>", ("<<", var("i"), const(1)),
                                          const(1)))))))
    return [
        ("attn-AF+RF", attn_variant, "flash_attention"),
        ("int8-exact", lib["int8_matvec"].term, "int8_matvec"),
        ("int8-unroll(2)", unrolled, "int8_matvec"),
        ("int8-tiling(4)", tiled, "int8_matvec"),
        ("int8-nonaffine", shifted, "int8_matvec"),
        ("ssd-loop-carried", lib["ssd_step"].term, "ssd_step"),
        ("rmsnorm-exact", lib["rmsnorm"].term, "rmsnorm"),
    ]


def run() -> list[str]:
    rows = []
    lib = isax_library()
    for name, sw, want in _cases():
        t0 = time.perf_counter()
        res = compile_program(sw, lib, case=name)
        dt = (time.perf_counter() - t0) * 1e6
        s = res.stats
        ok = want in s.matched_isaxes
        rows.append(
            f"compile/{name},{dt:.0f},"
            f"int={s.internal_rewrites};ext={s.external_rewrites};"
            f"enodes={s.initial_enodes}->{s.saturated_enodes};"
            f"matched={ok}")
        assert ok, f"{name}: expected {want}, got {s.matched_isaxes}"
    return rows
