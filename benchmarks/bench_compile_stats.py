"""Table 3 analog — compilation statistics per case: control-flow difference,
internal/external rewrite counts, initial/saturated e-node counts, and
whether every pattern matched.  Mirrors the paper's robustness evaluation:
each case is a deliberately perturbed software variant.

Also sweeps the live dispatch path: a small continuous-batching serve run
over the default serve config with a ``pallas_interpret`` LoweringConfig, so
the ISAX match-rate and compile-cache hit-rate of the real inference hot
path are measured (and exported as ``BENCH_compile.json`` by
``benchmarks/run.py``)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.expr import arr, const, for_, var
from repro.core.offload import compile_program
from repro.targets import isax_library

# Per-run records for the BENCH_compile.json artifact; populated by run().
JSON_RECORDS: list[dict] = []


def _mv_body(iexpr):
    return ("store", arr("C"), iexpr,
            ("*", var("s_w"), ("matvec", arr("Wq"),
                               ("load", arr("X"), iexpr))))


def _cases():
    lib = {x.name: x for x in isax_library()}
    i = var("i")
    q = ("load", arr("Q"), i)
    s_noshift = ("/", ("exp", ("matvec", arr("K"), ("*", var("scale"), q))),
                 ("rowsum", ("exp", ("matvec", arr("K"),
                                     ("*", var("scale"), q)))))
    attn_variant = for_("i", const(0), var("n_q"), const(1),
                        ("store", arr("P"), i, s_noshift),
                        ("store", arr("O"), i,
                         ("matvec", ("transpose", arr("V")),
                          ("load", arr("P"), i))))
    unrolled = for_("i", const(0), const(8), const(2),
                    _mv_body(var("i")), _mv_body(("+", var("i"), const(1))))
    tiled = for_("it", const(0), const(8), const(4),
                 for_("j", var("it"), ("+", var("it"), const(4)), const(1),
                      _mv_body(var("j"))))
    shifted = for_("i", const(0), var("n"), const(1),
                   ("store", arr("C"), var("i"),
                    ("*", var("s_w"),
                     ("matvec", arr("Wq"),
                      ("load", arr("X"), (">>", ("<<", var("i"), const(1)),
                                          const(1)))))))
    from repro.compile.trace import trace_term
    return [
        ("attn-AF+RF", attn_variant, "flash_attention"),
        ("int8-exact", lib["int8_matvec"].term, "int8_matvec"),
        ("int8-unroll(2)", unrolled, "int8_matvec"),
        ("int8-tiling(4)", tiled, "int8_matvec"),
        ("int8-nonaffine", shifted, "int8_matvec"),
        ("ssd-loop-carried", lib["ssd_step"].term, "ssd_step"),
        ("rmsnorm-exact", lib["rmsnorm"].term, "rmsnorm"),
        # point-cloud domain: expanded-distance (AF) and neg∘min∘neg (RF)
        # software spellings must still land on the ISAXes
        ("fps-expanded-dist", trace_term("fps"), "fps"),
        ("ballq-expanded-dist", trace_term("ball_query"), "ball_query"),
        ("groupagg-negmin", trace_term("group_aggregate"), "group_agg"),
    ]


def _dispatch_sweep() -> list[str]:
    """Serve the default config through the e-graph dispatch pipeline
    (interpret-mode kernels, tiny shapes) and report match/hit rates."""
    from repro.compile import Dispatcher, LoweringConfig
    from repro.configs.base import reduced
    from repro.configs.registry import get_config
    from repro.serve.engine import ContinuousEngine
    from repro.serve.scheduler import make_poisson_workload

    disp = Dispatcher()  # fresh cache: rates reflect this sweep only
    lowering = LoweringConfig.from_registry("pallas_interpret",
                                            dispatcher=disp)
    cfg = reduced(get_config("llama110m"))
    t0 = time.perf_counter()
    eng = ContinuousEngine(cfg, max_batch=2, page_size=16, max_len=64,
                           prompt_buckets=(16,), seed=0, lowering=lowering)
    reqs = make_poisson_workload(4, rate=2.0, vocab=cfg.vocab,
                                 prompt_lens=(8, 16), out_lens=(2, 4),
                                 seed=0)
    eng.run(reqs)
    # a second serve run re-traces nothing and re-lowers nothing, but a
    # fresh engine (new jit traces, same shapes) exercises the cache hits
    eng2 = ContinuousEngine(cfg, max_batch=2, page_size=16, max_len=64,
                            prompt_buckets=(16,), seed=0, lowering=lowering)
    eng2.run(make_poisson_workload(4, rate=2.0, vocab=cfg.vocab,
                                   prompt_lens=(8, 16), out_lens=(2, 4),
                                   seed=1))
    # fold the point-cloud vertical into the same cache, so the reported
    # match-rate spans both application domains (multi-application ISAX
    # coverage — the retargetable-compiler claim under test)
    B, N, M, K, C = 1, 256, 64, 8, 32
    for op, shape in (("fps", (B, N, M)),
                      ("ball_query", (B, N, M, K)),
                      ("group_aggregate", (B, N, M, K, C))):
        rec = lowering.lower(op, shape, "float32")
        assert rec.impl == "isax", f"{op} did not extract: {rec.note}"
    dt = (time.perf_counter() - t0) * 1e6
    st = disp.stats()
    assert st["match_rate"] > 0, (
        "expected a nonzero ISAX match-rate on the default serve config")
    assert st["cache_hits"] > 0, "second engine should hit the compile cache"
    JSON_RECORDS.append({
        "scenario": "dispatch_sweep/llama110m_continuous+pointcloud",
        "backend": "pallas_interpret",
        **st,
    })
    return [
        f"compile/dispatch_sweep,{dt:.0f},serve_default_cfg",
        f"compile/dispatch_match_rate,{st['match_rate'] * 1e6:.0f},"
        f"matched={st['matched_keys']}/{st['n_keys']}_keys",
        f"compile/dispatch_isax_rate,{st['isax_rate'] * 1e6:.0f},"
        f"isax_extracted={st['isax_keys']}/{st['n_keys']}_keys",
        f"compile/dispatch_hit_rate,{st['hit_rate'] * 1e6:.0f},"
        f"hits={st['cache_hits']};misses={st['cache_misses']}",
        f"compile/dispatch_pipelined_rate,"
        f"{st['pipelined_keys'] / max(st['n_keys'], 1) * 1e6:.0f},"
        f"burst_dma_selected={st['pipelined_keys']}/{st['n_keys']}_keys",
    ]


def run() -> list[str]:
    rows = []
    lib = isax_library()
    JSON_RECORDS.clear()
    for name, sw, want in _cases():
        t0 = time.perf_counter()
        res = compile_program(sw, lib, case=name)
        dt = (time.perf_counter() - t0) * 1e6
        s = res.stats
        ok = want in s.matched_isaxes
        rows.append(
            f"compile/{name},{dt:.0f},"
            f"int={s.internal_rewrites};ext={s.external_rewrites};"
            f"enodes={s.initial_enodes}->{s.saturated_enodes};"
            f"matched={ok}")
        assert ok, f"{name}: expected {want}, got {s.matched_isaxes}"
        JSON_RECORDS.append({
            "scenario": f"table3/{name}",
            "internal_rewrites": s.internal_rewrites,
            "external_rewrites": s.external_rewrites,
            "initial_enodes": s.initial_enodes,
            "saturated_enodes": s.saturated_enodes,
            "matched": list(s.matched_isaxes),
            "us": dt,
        })
    rows.extend(_dispatch_sweep())
    return rows
