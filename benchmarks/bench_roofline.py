"""Roofline report — aggregates the dry-run artifacts (runs/dryrun/*.json)
into the per-(arch × shape × mesh) three-term table for EXPERIMENTS.md."""

from __future__ import annotations

import glob
import json
import os


def load_records(out_dir: str = "runs/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs: list[dict]) -> str:
    lines = ["arch,shape,mesh,ok,compute_s,memory_s,collective_s,"
             "bottleneck,useful_ratio,args_GB,compile_s"]
    for r in recs:
        rl = r.get("roofline", {})
        mem = r.get("memory", {})
        args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{int(r['ok'])},"
            f"{rl.get('compute_s', 0):.4f},{rl.get('memory_s', 0):.4f},"
            f"{rl.get('collective_s', 0):.4f},{rl.get('bottleneck', '-')},"
            f"{rl.get('useful_ratio', 0):.3f},{args_gb:.2f},"
            f"{r.get('compile_s', 0):.1f}")
    return "\n".join(lines)


def run() -> list[str]:
    recs = load_records()
    if not recs:
        return ["roofline/cells,0,no dryrun artifacts (run "
                "python -m repro.launch.dryrun first)"]
    ok = sum(r["ok"] for r in recs)
    rows = [f"roofline/cells,{len(recs)},ok={ok}"]
    bottlenecks: dict[str, int] = {}
    for r in recs:
        b = r.get("roofline", {}).get("bottleneck", "-")
        bottlenecks[b] = bottlenecks.get(b, 0) + 1
    for b, n in sorted(bottlenecks.items()):
        rows.append(f"roofline/bottleneck_{b},{n},cells")
    return rows
