"""Table 2 analog — per-'ISAX' speedups, measured end-to-end through the
retargetable compiler.

Baseline = the mini-IR program executed op-at-a-time by the evaluator (the
"base core": one operation per issue, no fusion).  Aquas = the SAME program
after ``compile_program`` offloads it to the fused kernel datapaths.  The
speedup is therefore attributable to the compiler finding the offload, which
is the paper's Table-2 claim shape (RTL cycle counts are not reproducible on
CPU; relative speedup is the comparable quantity).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.expr import arr, const, for_, var
from repro.core.offload import compile_program, evaluate
from repro.targets import isax_library
from repro.kernels.ops import register_kernel_intrinsics

register_kernel_intrinsics()


def _time(fn, reps=3, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _attention_case():
    i = var("i")
    q = ("load", arr("Q"), i)
    s = ("/", ("exp", ("matvec", arr("K"), ("*", var("scale"), q))),
         ("rowsum", ("exp", ("matvec", arr("K"), ("*", var("scale"), q)))))
    sw = for_("i", const(0), var("n_q"), const(1),
              ("store", arr("P"), i, s),
              ("store", arr("O"), i,
               ("matvec", ("transpose", arr("V")), ("load", arr("P"), i))))
    rng = np.random.default_rng(0)
    nq, nk, d = 64, 256, 64
    env = dict(Q=rng.normal(size=(nq, d)), K=rng.normal(size=(nk, d)),
               V=rng.normal(size=(nk, d)), scale=d ** -0.5, n_q=nq,
               P=np.zeros((nq, nk)), O=np.zeros((nq, d)))
    return "flash_attention", sw, env, ["O"]


def _int8_case():
    sw = for_("i", const(0), var("n"), const(1),
              ("store", arr("C"), var("i"),
               ("*", var("s_w"), ("matvec", arr("Wq"),
                                  ("load", arr("X"), var("i"))))))
    rng = np.random.default_rng(1)
    n, m, k = 128, 256, 256
    env = dict(Wq=rng.integers(-127, 127, size=(m, k)).astype(np.int8),
               X=rng.normal(size=(n, k)), s_w=0.02, n=n, C=np.zeros((n, m)))
    return "int8_matvec", sw, env, ["C"]


def _ssd_case():
    lib = {x.name: x for x in isax_library()}
    ix = lib["ssd_step"]
    rng = np.random.default_rng(2)
    T, n, p = 256, 32, 16
    env = dict(A=rng.uniform(0.2, 0.9, size=(T,)),
               B=rng.normal(size=(T, n)), C=rng.normal(size=(T, n)),
               X=rng.normal(size=(T, p)), T=T, H=np.zeros((1, n, p)),
               Y=np.zeros((T, p)))
    return "ssd_step", ix.term, env, ["Y"]


def _rms_case():
    lib = {x.name: x for x in isax_library()}
    ix = lib["rmsnorm"]
    rng = np.random.default_rng(3)
    n, d = 256, 512
    env = dict(Xn=rng.normal(size=(n, d)), G=rng.normal(size=(d,)),
               eps=1e-6, n=n, On=np.zeros((n, d)))
    return "rmsnorm", ix.term, env, ["On"]


def run() -> list[str]:
    rows = []
    lib = isax_library()
    for case_fn in (_attention_case, _int8_case, _ssd_case, _rms_case):
        name, sw, env0, outs = case_fn()
        res = compile_program(sw, lib, case=name)
        matched = name.split("_")[0] in ",".join(res.stats.matched_isaxes) \
            or res.stats.matched_isaxes

        def mk_env():
            return {k: (v.copy() if isinstance(v, np.ndarray) else v)
                    for k, v in env0.items()}

        base_us = _time(lambda: evaluate(sw, mk_env()))
        aquas_us = _time(lambda: evaluate(res.program, mk_env()))
        # correctness gate
        e0, e1 = mk_env(), mk_env()
        evaluate(sw, e0)
        evaluate(res.program, e1)
        err = max(float(np.max(np.abs(e0[o] - e1[o]))) for o in outs)
        assert err < 1e-3, (name, err)
        speedup = base_us / max(aquas_us, 1e-9)
        rows.append(f"kernels/{name}_base,{base_us:.1f},matched="
                    f"{bool(matched)}")
        rows.append(f"kernels/{name}_aquas,{aquas_us:.1f},"
                    f"speedup={speedup:.2f}x")
    return rows
