"""Memory-bandwidth pipeline sweep: burst-DMA pipelined vs unpipelined
kernels across memory-bound shapes (the hardware-side analog of the paper's
fast-memory-access evaluation).

For each kernel (flash attention, int8 matmul, SSD scan) and each shape the
sweep runs both kernel paths — plain BlockSpec streaming and the explicit
``kernels/pipeline.py`` multi-buffered DMA pipeline — checks numerical
parity, and records wall time next to the synthesis cost model's verdict
(chosen depth, predicted gain, interface-model cycle estimates).

Off-TPU the kernels execute in interpret mode, so the wall times measure
the Pallas interpreter's DMA emulation, not TPU DMA overlap — the
``est_*_cycles`` / ``predicted_gain`` columns carry the modeled gap the
pipeline exists to close.  On a TPU host the kernels compile and the wall
times are real.  ``benchmarks/run.py --only membw`` writes the records to
``BENCH_membw.json``.

Env: BENCH_SMOKE=0 for full sizes.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernel_synth import (
    PIPELINE_GAIN_MIN,
    choose_flash_blocks,
    choose_matmul_blocks,
    choose_ssd_blocks,
)
from repro.kernels import ops

# Per-run records for the BENCH_membw.json artifact; populated by run().
JSON_RECORDS: list[dict] = []

#: One-line run verdict printed by benchmarks/run.py after the CSV rows;
#: set by run() so interpret-mode sweeps never read like a measured win.
SUMMARY: str | None = None

_SMOKE = os.environ.get("BENCH_SMOKE", "1") != "0"

#: Interpret off-TPU (the Pallas interpreter emulates the DMA semaphores);
#: compile for real on a TPU host so the wall times measure actual overlap.
_INTERPRET = jax.default_backend() != "tpu"

#: Memory-bound shapes: short query / skinny activation against a long
#: streamed operand, so DMA bytes dominate the MXU work.  Full sizes stay
#: modest because off-TPU runs pay interpreter cost per grid step.
_FLASH_SHAPES = ([(1, 64, 2, 2, 512, 64)] if _SMOKE else
                 [(1, 128, 4, 4, 1024, 64), (1, 128, 8, 8, 2048, 64)])
_INT8_SHAPES = ([(32, 256, 8192)] if _SMOKE else
                [(64, 1024, 8192), (64, 2048, 8192)])
_SSD_SHAPES = ([(1, 2, 1024, 16, 16)] if _SMOKE else
               [(1, 4, 2048, 32, 32)])

_RNG = np.random.default_rng(0)


def _time(fn, *args, iters: int = 3, **kw) -> tuple[float, np.ndarray]:
    out = fn(*args, **kw)            # warmup (trace + compile/interpret)
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        out.block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    return dt * 1e6, np.asarray(out, np.float32)


def _record(kernel: str, shape, sched, pip_us: float, unpip_us: float,
            max_err: float) -> str:
    assert sched.pipelined == (sched.pipeline_gain >= PIPELINE_GAIN_MIN
                               and sched.buffering > 1), (
        "pipeline must never be auto-selected on a predicted loss")
    JSON_RECORDS.append({
        "scenario": f"membw/{kernel}",
        "shape": list(shape),
        "pipelined_us": pip_us,
        "unpipelined_us": unpip_us,
        "selected": sched.pipelined,
        # the depth the *selected* path actually runs at (1 = plain
        # BlockSpec streaming when the pipeline is vetoed)
        "depth": sched.buffering,
        # the forced `pipelined=True` timing above always runs with at
        # least two buffers (ops.* use max(2, buffering)) — record that
        # separately so a vetoed record never claims a deeper default path
        "forced_pipelined_depth": max(2, sched.buffering),
        "predicted_gain": sched.pipeline_gain,
        "est_pipelined_cycles": sched.est_total_cycles,
        "est_serial_cycles": sched.est_serial_cycles,
        "max_abs_err": max_err,
        "interpret": _INTERPRET,
        # off-TPU the wall times measure the Pallas interpreter's DMA
        # emulation, not DMA overlap — this run is a parity check
        "timing_meaningful": not _INTERPRET,
    })
    return (f"membw/{kernel},{unpip_us:.0f},"
            f"pipelined={pip_us:.0f}us"
            f"(forced,depth={max(2, sched.buffering)});"
            f"selected_depth={sched.buffering};"
            f"predicted_gain={sched.pipeline_gain:.2f}x;"
            f"selected={sched.pipelined};err={max_err:.2e}")


def run() -> list[str]:
    """Sweep pipelined vs unpipelined kernels; returns CSV rows."""
    global SUMMARY
    rows = []
    JSON_RECORDS.clear()
    SUMMARY = ("interpret-mode parity check — wall times measure the Pallas "
               "interpreter's DMA emulation, not TPU overlap (see the "
               "est_*_cycles columns for the modeled gap)" if _INTERPRET
               else "pipelined vs unpipelined measured on TPU")

    for B, S, H, K, T, hd in _FLASH_SHAPES:
        q = jnp.asarray(_RNG.normal(size=(B, S, H, hd)), jnp.float32)
        k = jnp.asarray(_RNG.normal(size=(B, T, K, hd)), jnp.float32)
        v = jnp.asarray(_RNG.normal(size=(B, T, K, hd)), jnp.float32)
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)[None]
        sched = choose_flash_blocks(S, T, hd, 4)
        pip_us, got = _time(ops.flash_attention_gqa, q, k, v, mask,
                            sm_scale=hd ** -0.5, interpret=_INTERPRET,
                            pipelined=True)
        unpip_us, want = _time(ops.flash_attention_gqa, q, k, v, mask,
                               sm_scale=hd ** -0.5, interpret=_INTERPRET,
                               pipelined=False)
        err = float(np.abs(got - want).max())
        assert err < 1e-5, f"flash pipelined diverged: {err}"
        rows.append(_record("flash_attention", (B, S, H, K, T, hd), sched,
                            pip_us, unpip_us, err))

    for M, N, Kd in _INT8_SHAPES:
        x = jnp.asarray(_RNG.normal(size=(M, Kd)), jnp.float32)
        wq = jnp.asarray(_RNG.integers(-127, 127, size=(N, Kd)), jnp.int8)
        sc = jnp.asarray(_RNG.uniform(0.01, 0.02, size=(N,)), jnp.float32)
        sched = choose_matmul_blocks(M, N, Kd, dtype_bytes=1)
        pip_us, got = _time(ops.int8_matmul, x, wq, sc, interpret=_INTERPRET,
                            pipelined=True)
        unpip_us, want = _time(ops.int8_matmul, x, wq, sc, interpret=_INTERPRET,
                               pipelined=False)
        err = float(np.abs(got - want).max())
        assert err < 1e-4, f"int8 pipelined diverged: {err}"
        rows.append(_record("int8_matmul", (M, N, Kd), sched,
                            pip_us, unpip_us, err))

    for BT, H, S, P, N in _SSD_SHAPES:
        x = jnp.asarray(_RNG.normal(size=(BT, H, S, P)), jnp.float32)
        dt = jnp.asarray(_RNG.uniform(0.01, 0.1, size=(BT, H, S)),
                         jnp.float32)
        A = jnp.asarray(-_RNG.uniform(0.5, 1.5, size=(H,)), jnp.float32)
        Bm = jnp.asarray(_RNG.normal(size=(BT, S, N)), jnp.float32)
        Cm = jnp.asarray(_RNG.normal(size=(BT, S, N)), jnp.float32)
        sched = choose_ssd_blocks(S, H, P, N)
        pip_us, got = _time(ops.ssd_scan, x, dt, A, Bm, Cm, interpret=_INTERPRET,
                            pipelined=True)
        unpip_us, want = _time(ops.ssd_scan, x, dt, A, Bm, Cm,
                               interpret=_INTERPRET, pipelined=False)
        err = float(np.abs(got - want).max())
        assert err < 1e-3, f"ssd pipelined diverged: {err}"
        rows.append(_record("ssd_scan", (BT, H, S, P, N), sched,
                            pip_us, unpip_us, err))

    return rows
