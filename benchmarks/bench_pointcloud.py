"""Point-cloud vertical sweep: reference vs Pallas vs burst-pipelined for
farthest-point sampling, ball query, and grouped feature aggregation — the
irregular gather/scatter workloads of the paper's second application domain.

Every op runs through the e-graph dispatch path (``LoweringConfig`` with a
fresh ``Dispatcher``), so the sweep also verifies that the point-cloud keys
resolve as extracted ISAX kernels; the match-rate itself is folded into
``bench_compile_stats`` / ``BENCH_compile.json`` alongside the LLM keys.

Off-TPU the kernels execute in interpret mode, so wall times measure the
Pallas interpreter, not the hardware (``timing_meaningful: false`` on every
record; the synthesized ``predicted_gain`` columns carry the modeled story).
``benchmarks/run.py --only pointcloud`` writes ``BENCH_pointcloud.json``.

Env: BENCH_SMOKE=0 for full sizes.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

# Per-run records for the BENCH_pointcloud.json artifact; populated by run().
JSON_RECORDS: list[dict] = []

#: One-line run verdict printed by benchmarks/run.py after the CSV rows.
SUMMARY: str | None = None

_SMOKE = os.environ.get("BENCH_SMOKE", "1") != "0"
_INTERPRET = jax.default_backend() != "tpu"

#: (B, n_points, n_centers, k, channels): long point/feature arrays against
#: small per-center state — the memory-bound gather shapes the burst DMA
#: engine exists for.  Smoke stays tiny (interpret mode pays per grid step).
_SHAPES = ([(1, 256, 64, 8, 32)] if _SMOKE else
           [(2, 2048, 256, 16, 64), (2, 4096, 512, 16, 64)])

_RNG = np.random.default_rng(0)


def _time(fn, *args, iters: int = 3, **kw) -> tuple[float, np.ndarray]:
    out = fn(*args, **kw)            # warmup (trace + compile/interpret)
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        out.block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    return dt * 1e6, np.asarray(out)


def _record(op: str, shape, rec, ref_us: float, pallas_us: float,
            pipelined_us: float | None, exact: bool) -> str:
    sched = rec.schedule or {}
    JSON_RECORDS.append({
        "scenario": f"pointcloud/{op}",
        "shape": list(shape),
        "impl": rec.impl,
        "matched": list(rec.matched),
        "ref_us": ref_us,
        "pallas_us": pallas_us,
        "pipelined_us": pipelined_us,
        "selected": bool(sched.get("pipelined", False)),
        "depth": sched.get("buffering", 1),
        "predicted_gain": sched.get("pipeline_gain", 1.0),
        "parity_exact": exact,
        "interpret": _INTERPRET,
        "timing_meaningful": not _INTERPRET,
    })
    pip = "n/a" if pipelined_us is None else f"{pipelined_us:.0f}us"
    return (f"pointcloud/{op},{ref_us:.0f},"
            f"pallas={pallas_us:.0f}us;pipelined={pip};"
            f"impl={rec.impl};depth={sched.get('buffering', 1)};"
            f"selected={sched.get('pipelined', False)};exact={exact}")


def run() -> list[str]:
    """Sweep the point-cloud ops through dispatch; returns CSV rows."""
    global SUMMARY
    from repro.compile import Dispatcher, LoweringConfig
    from repro.pointcloud import ops as pcops
    from repro.pointcloud import ref as pcref

    rows = []
    JSON_RECORDS.clear()
    SUMMARY = ("interpret-mode parity check — wall times measure the Pallas "
               "interpreter, not the hardware (predicted_gain carries the "
               "modeled story)" if _INTERPRET
               else "point-cloud ops measured on TPU")
    backend = "pallas_interpret" if _INTERPRET else "pallas"
    disp = Dispatcher()  # fresh cache: records reflect this sweep only
    lw = LoweringConfig.from_registry(backend, dispatcher=disp)

    for B, N, M, K, C in _SHAPES:
        xyz = jnp.asarray(_RNG.normal(size=(B, N, 3)), jnp.float32)
        feats = jnp.asarray(_RNG.normal(size=(B, N, C)), jnp.float32)

        # -- farthest-point sampling --------------------------------------
        ref_us, want = _time(pcref.fps_ref, xyz, M)
        pal_us, got = _time(lw.fps, xyz, M)
        rec = lw.lower("fps", (B, N, M), "float32")
        exact = bool((got == want).all())
        assert exact, "fps diverged from the reference"
        assert rec.impl == "isax", f"fps did not extract: {rec.note}"
        rows.append(_record("fps", (B, N, M), rec, ref_us, pal_us,
                            None, exact))
        centers = jnp.take_along_axis(xyz, jnp.asarray(want)[..., None],
                                      axis=1)

        # -- ball query ----------------------------------------------------
        radius = 0.9
        ref_us, want = _time(pcref.ball_query_ref, xyz, centers, radius, K)
        pal_us, got = _time(pcops.ball_query, xyz, centers, radius, K,
                            interpret=_INTERPRET, pipelined=False)
        pip_us, gotp = _time(pcops.ball_query, xyz, centers, radius, K,
                             interpret=_INTERPRET, pipelined=True)
        rec = lw.lower("ball_query", (B, N, M, K), "float32")
        exact = bool((got == want).all()) and bool((gotp == want).all())
        assert exact, "ball_query diverged from the reference"
        rows.append(_record("ball_query", (B, N, M, K), rec, ref_us, pal_us,
                            pip_us, exact))
        idx = jnp.asarray(want)

        # -- grouped feature aggregation ----------------------------------
        ref_us, wantg = _time(pcref.group_aggregate_ref, feats, idx)
        pal_us, gotg = _time(pcops.group_aggregate, feats, idx,
                             interpret=_INTERPRET, pipelined=False)
        pip_us, gotgp = _time(pcops.group_aggregate, feats, idx,
                              interpret=_INTERPRET, pipelined=True)
        rec = lw.lower("group_aggregate", (B, N, M, K, C), "float32")
        err = max(float(np.abs(gotg - wantg).max()),
                  float(np.abs(gotgp - wantg).max()))
        assert err == 0.0, f"group_aggregate diverged: {err}"
        rows.append(_record("group_aggregate", (B, N, M, K, C), rec,
                            ref_us, pal_us, pip_us, err == 0.0))

    st = disp.stats()
    assert st["match_rate"] == 1.0, (
        "every point-cloud key should match its ISAX")
    rows.append(
        f"pointcloud/dispatch_match_rate,{st['match_rate'] * 1e6:.0f},"
        f"matched={st['matched_keys']}/{st['n_keys']}_keys;"
        f"pipelined={st['pipelined_keys']}")
    return rows
