"""Benchmark harness — one module per paper table/figure.

    Table 2  → bench_kernels       (per-ISAX speedups via the compiler)
    Table 3  → bench_compile_stats (e-graph compilation statistics)
    Fig 2/3  → bench_synthesis     (interface-model decision quality)
    Fig 8    → bench_llm_serve     (LLM TTFT/ITL, int8)
    §Roofline→ bench_roofline      (dry-run aggregate)

Prints ``name,us_per_call,derived`` CSV.  Env: BENCH_SMOKE=0 for full sizes.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_compile_stats, bench_kernels,
                            bench_llm_serve, bench_roofline, bench_synthesis)
    modules = [
        ("synthesis", bench_synthesis),
        ("kernels", bench_kernels),
        ("compile_stats", bench_compile_stats),
        ("llm_serve", bench_llm_serve),
        ("roofline", bench_roofline),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in modules:
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception as e:
            failed += 1
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
