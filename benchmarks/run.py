"""Benchmark harness — one module per paper table/figure.

    Table 2  → bench_kernels       (per-ISAX speedups via the compiler)
    Table 3  → bench_compile_stats (e-graph compilation statistics)
    Fig 2/3  → bench_synthesis     (interface-model decision quality)
    Fig 8    → bench_llm_serve     (LLM TTFT/ITL, int8, continuous batching)
    §HW mem  → bench_membw         (burst-DMA pipelined vs unpipelined)
    §Roofline→ bench_roofline      (dry-run aggregate)

Prints ``name,us_per_call,derived`` CSV.  Modules with a ``JSON_RECORDS``
list get their per-scenario records written to a JSON artifact so CI can
archive the perf trajectory: ``llm_serve`` → ``BENCH_serve.json`` (schema:
scenario, ttft_s, itl_s, tokens_per_s, …), ``compile_stats`` →
``BENCH_compile.json`` (Table-3 rows plus the dispatch sweep's ISAX
match-rate / compile-cache hit-rate / burst-pipeline selections),
``membw`` → ``BENCH_membw.json`` (pipelined vs unpipelined time per kernel
with the cost model's predicted gain), and ``pointcloud`` →
``BENCH_pointcloud.json`` (reference vs Pallas vs burst-pipelined for the
point-cloud vertical).  Off-TPU the kernel sweeps run in interpret mode and
carry ``timing_meaningful: false``; modules flag that with a ``SUMMARY``
line printed after their rows.

Env: BENCH_SMOKE=0 for full sizes.  ``--only <name>[,<name>…]`` restricts
to a subset of modules (e.g. ``--only llm_serve,compile_stats`` in CI).
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

ARTIFACTS = {
    "llm_serve": "BENCH_serve.json",
    "compile_stats": "BENCH_compile.json",
    "membw": "BENCH_membw.json",
    "pointcloud": "BENCH_pointcloud.json",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset to run")
    ap.add_argument("--artifact-dir", default=".",
                    help="where to write BENCH_serve.json")
    args = ap.parse_args()

    from benchmarks import (bench_compile_stats, bench_kernels,
                            bench_llm_serve, bench_membw, bench_pointcloud,
                            bench_roofline, bench_synthesis)
    modules = [
        ("synthesis", bench_synthesis),
        ("kernels", bench_kernels),
        ("compile_stats", bench_compile_stats),
        ("membw", bench_membw),
        ("pointcloud", bench_pointcloud),
        ("llm_serve", bench_llm_serve),
        ("roofline", bench_roofline),
    ]
    if args.only:
        wanted = set(args.only.split(","))
        valid = [n for n, _ in modules]
        unknown = wanted - set(valid)
        if unknown:
            raise SystemExit(
                f"unknown bench module(s): {sorted(unknown)}; "
                f"valid: {valid}")
        modules = [(n, m) for n, m in modules if n in wanted]
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in modules:
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception as e:
            failed += 1
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        # run verdict (e.g. membw's "interpret-mode parity check" note, so
        # interpreter wall times are never mistaken for a measured win)
        summary = getattr(mod, "SUMMARY", None)
        if summary:
            print(f"# {name}: {summary}", flush=True)
        artifact = ARTIFACTS.get(name)
        if artifact and getattr(mod, "JSON_RECORDS", None):
            path = f"{args.artifact_dir}/{artifact}"
            with open(path, "w") as f:
                json.dump(mod.JSON_RECORDS, f, indent=2)
            print(f"# wrote {path} ({len(mod.JSON_RECORDS)} records)",
                  flush=True)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
