"""Figure 2/3 analog — interface-aware synthesis decision quality.

Reports, for the paper's fir7 example and a TPU GEMM staging workload:
naive single-interface schedules vs the synthesized schedule (model cycles),
plus synthesis wall time.  The paper's claim: model-guided selection +
ordering beats first-glance manual choices."""

from __future__ import annotations

import time

from repro.core import aquas_ir as ir
from repro.core.interface_model import (paper_example_interfaces,
                                        sequence_latency, tpu_interfaces)
from repro.core.kernel_synth import (choose_flash_blocks,
                                     choose_matmul_blocks, choose_ssd_blocks)
from repro.core.synthesis import synthesize


def _fir7():
    sp = {
        "bias": ir.ScratchpadDecl("bias", 28, ir.CacheHint.WARM,
                                  compute_cycles_per_elem=8.0, elem_bytes=4),
        "coef": ir.ScratchpadDecl("coef", 28, ir.CacheHint.WARM,
                                  reuse_factor=7, elem_bytes=4),
    }
    ops = [
        ir.FuncOp("transfer", "src", 108, ir.Space.GLOBAL,
                  ir.Space.SCRATCHPAD, "load", ir.CacheHint.COLD),
        ir.FuncOp("transfer", "coef", 28, ir.Space.GLOBAL,
                  ir.Space.SCRATCHPAD, "load", ir.CacheHint.WARM,
                  scratchpad="coef"),
        ir.FuncOp("transfer", "bias", 28, ir.Space.GLOBAL,
                  ir.Space.SCRATCHPAD, "load", ir.CacheHint.WARM,
                  scratchpad="bias"),
        ir.FuncOp("read_smem", "bias_rd", 28, ir.Space.SCRATCHPAD,
                  ir.Space.REG, "load", scratchpad="bias"),
        ir.FuncOp("transfer", "dst", 80, ir.Space.REG, ir.Space.GLOBAL,
                  "store", ir.CacheHint.COLD),
    ]
    return ir.FunctionalProgram("fir7", ops, sp)


def run() -> list[str]:
    rows = []
    itfcs = paper_example_interfaces()

    # naive: everything over the cpu port, program order
    cpu = itfcs["cpuitfc"]
    naive = sum(sequence_latency(cpu, cpu.decompose(m), d)
                for m, d in [(108, "load"), (28, "load"), (28, "load"),
                             (80, "store")])
    t0 = time.perf_counter()
    t = synthesize(_fir7(), itfcs)
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(f"synthesis/fir7,{dt:.0f},"
                f"naive={naive}cyc;aquas={t.total_cycles:.0f}cyc;"
                f"gain={naive / t.total_cycles:.2f}x")

    # TPU staging workload
    itfcs_t = tpu_interfaces()
    prog = ir.FunctionalProgram("gemm_staging", [
        ir.FuncOp("transfer", "w_tile", 8 << 20, ir.Space.GLOBAL,
                  ir.Space.SCRATCHPAD, "load", ir.CacheHint.COLD),
        ir.FuncOp("transfer", "x_tile", 2 << 20, ir.Space.GLOBAL,
                  ir.Space.SCRATCHPAD, "load", ir.CacheHint.WARM),
        ir.FuncOp("transfer", "y_tile", 2 << 20, ir.Space.REG,
                  ir.Space.GLOBAL, "store", ir.CacheHint.COLD)], {})
    t0 = time.perf_counter()
    t2 = synthesize(prog, itfcs_t)
    dt2 = (time.perf_counter() - t0) * 1e6
    ici = itfcs_t["ici_link"]
    naive2 = sequence_latency(ici, ici.decompose(12 << 20), "load")
    rows.append(f"synthesis/tpu_gemm_staging,{dt2:.0f},"
                f"naive_ici={naive2}cyc;aquas={t2.total_cycles:.0f}cyc")

    # kernel schedule synthesis (BlockSpec decisions)
    for nm, fn in [
        ("matmul_4k", lambda: choose_matmul_blocks(4096, 4096, 4096)),
        ("flash_4k", lambda: choose_flash_blocks(4096, 4096, 128)),
        ("ssd_4k", lambda: choose_ssd_blocks(4096, 80, 64, 128)),
    ]:
        t0 = time.perf_counter()
        s = fn()
        dt = (time.perf_counter() - t0) * 1e6
        rows.append(f"synthesis/{nm},{dt:.0f},"
                    f"blocks={s.block_shapes};buf={s.buffering};"
                    f"bound={s.decisions['bound']}")
    return rows
