"""Declarative ISAX/domain registry — the retargetable-lowering backbone.

The paper's headline compiler claim is *retargetability*: a new ISAX or a
new application domain should plug into the e-graph matching engine, not be
hand-wired through it.  This module is the plug: everything one ISAX needs
is bundled in a frozen :class:`IsaxSpec` —

* the skeleton/component definition (``core/matching.ISAX`` factory),
* evaluator semantics (the numpy oracle ``core/offload.evaluate`` binds),
* the bridging internal rewrites its software spellings rely on,
* the divergent trace-program builder and its saturation memo kind,
* the ``core/kernel_synth`` scheduler, and
* the baseline / burst-pipelined Pallas entry points

— and a :class:`DomainPackage` registers a set of specs into the global
registry at import time (``repro.targets`` imports the built-in ``llm`` and
``pointcloud`` domains).  ``compile/dispatch.py`` is a generic engine over
registered specs: it holds no per-domain imports, no per-op ``if`` ladders,
and no hand-maintained scheduler/kernel dicts.  Adding a domain means
writing one module with a ``DomainPackage`` and registering it — the
acceptance test for this design registers a toy third domain in a single
file and is matched, scheduled, cached, and dispatched by the unchanged
engine.

Spec objects use *identity* semantics (``eq=False``): the dispatcher's
saturation memo is keyed on the spec object itself, so two domains can
never alias a trace kind by picking the same kind string.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Optional

if TYPE_CHECKING:  # OpKey/ISAX are type-only: targets never imports compile
    from repro.compile.trace import OpKey
    from repro.core.matching import ISAX

#: scheduler contract: OpKey -> (schedule dict, "ok") or (None, why-not).
SchedulerFn = Callable[["OpKey"], "tuple[Optional[dict], str]"]


@dataclasses.dataclass(frozen=True)
class ChunkedLowering:
    """Declarative ``xla_chunked`` policy for ops that have a chunked XLA
    lowering (today: the attention family's online-softmax scan).

    ``axis`` is the OpKey.shape axis that must exceed 1 for chunking to be
    worthwhile; below that the engine records ``fallback_note`` and keeps
    the reference.
    """

    axis: int
    note: str
    fallback_note: str


@dataclasses.dataclass(frozen=True, eq=False)
class IsaxSpec:
    """One ISAX (or reference-only op family), fully self-contained.

    ``isax=None`` declares a *negative control*: ops that trace and
    saturate like everything else but deliberately have no specialized
    datapath (their target is ``None`` and they must lower to the XLA
    reference).  ``ops=()`` declares a library-only ISAX that participates
    in matching/evaluation but has no dispatch key yet (e.g. ``swiglu``).

    Identity semantics (``eq=False``): the spec object *is* the saturation
    memo key, so equal-looking specs from different domains never share an
    e-graph outcome.
    """

    name: str
    isax: Optional[Callable[[], "ISAX"]] = None
    evaluator: Optional[Callable] = None
    trace_kind: Optional[str] = None
    trace_program: Optional[Callable[[], tuple]] = None
    ops: tuple[str, ...] = ()
    rewrites: tuple[str, ...] = ()
    scheduler: Optional[SchedulerFn] = None
    kernel: Optional[Callable] = None
    kernel_pipelined: Optional[Callable] = None
    chunked: Optional[ChunkedLowering] = None
    op_notes: tuple[tuple[str, str], ...] = ()
    description: str = ""
    domain: Optional[str] = None  # stamped by the registry at registration

    @property
    def target(self) -> Optional[str]:
        """ISAX name the ops are expected to extract, or None (negative
        control / reference-only op)."""
        return self.name if self.isax is not None else None

    def note_for(self, op: str) -> str:
        """Free-form doc note for one dispatch op (used by the generated
        op → ISAX table)."""
        return dict(self.op_notes).get(op, "")

    def validate(self) -> None:
        """Raise ValueError unless the spec is complete enough to dispatch.

        Every spec that owns dispatch ops needs a trace program (the engine
        must be able to saturate it); every *matchable* spec (``isax`` set)
        additionally needs evaluator semantics, and — when it owns ops — a
        scheduler and a resolvable kernel entry point.
        """
        if not self.name:
            raise ValueError("IsaxSpec needs a non-empty name")
        if self.ops:
            if self.trace_program is None or not self.trace_kind:
                raise ValueError(
                    f"spec {self.name!r} owns ops {self.ops} but has no "
                    "trace_program/trace_kind")
        if self.isax is not None:
            built = self.isax()
            if built.name != self.name:
                raise ValueError(
                    f"spec {self.name!r} builds an ISAX named "
                    f"{built.name!r}; names must agree")
            if self.evaluator is None:
                raise ValueError(
                    f"spec {self.name!r} has no evaluator semantics")
            if self.ops and (self.scheduler is None or self.kernel is None):
                raise ValueError(
                    f"spec {self.name!r} owns ops {self.ops} but is missing "
                    f"{'a scheduler' if self.scheduler is None else ''}"
                    f"{' and ' if self.scheduler is None and self.kernel is None else ''}"
                    f"{'a kernel entry point' if self.kernel is None else ''}")


@dataclasses.dataclass(frozen=True)
class DomainPackage:
    """A named application domain: an ordered set of IsaxSpecs registered
    together (``llm``, ``pointcloud``, your domain here)."""

    name: str
    specs: tuple[IsaxSpec, ...]
    description: str = ""


class TargetRegistry:
    """Ordered ISAX/domain registry the generic dispatch engine iterates.

    Invariants (enforced at ``register`` time, atomically — a rejected
    package leaves the registry untouched):

    * domain names are unique,
    * spec names are unique across all domains,
    * dispatch op names are unique across all domains,
    * every spec passes :meth:`IsaxSpec.validate`.

    ``isaxes()`` preserves registration order — saturation outcomes depend
    on library order, so the built-in domains register in the historical
    ``isax_library()`` order and new domains append after them.
    """

    def __init__(self):
        self._domains: dict[str, DomainPackage] = {}
        self._specs: dict[str, IsaxSpec] = {}
        self._ops: dict[str, IsaxSpec] = {}
        self._isax_cache: Optional[list] = None

    # -- registration -------------------------------------------------------

    def register(self, package: DomainPackage) -> DomainPackage:
        """Register a domain package; returns the bound (domain-stamped)
        package.  Raises ValueError on any name/op collision."""
        if package.name in self._domains:
            raise ValueError(f"domain {package.name!r} is already registered")
        bound_specs = []
        seen_names, seen_ops = set(), set()
        for spec in package.specs:
            spec = dataclasses.replace(spec, domain=package.name)
            spec.validate()
            if spec.name in self._specs or spec.name in seen_names:
                raise ValueError(
                    f"duplicate ISAX spec name {spec.name!r} "
                    f"(domain {package.name!r})")
            seen_names.add(spec.name)
            for op in spec.ops:
                if op in self._ops or op in seen_ops:
                    raise ValueError(
                        f"duplicate dispatch op {op!r} (domain "
                        f"{package.name!r}, spec {spec.name!r})")
                seen_ops.add(op)
            bound_specs.append(spec)
        bound = DomainPackage(package.name, tuple(bound_specs),
                              package.description)
        self._domains[bound.name] = bound
        for spec in bound.specs:
            self._specs[spec.name] = spec
            for op in spec.ops:
                self._ops[op] = spec
        self._isax_cache = None
        return bound

    # -- lookup -------------------------------------------------------------

    def domains(self) -> dict[str, DomainPackage]:
        """Registered domain packages by name (registration order)."""
        return dict(self._domains)

    def specs(self) -> list[IsaxSpec]:
        """All registered specs in registration order."""
        return list(self._specs.values())

    def spec(self, name: str) -> IsaxSpec:
        """Spec by ISAX name; KeyError with the known names otherwise."""
        try:
            return self._specs[name]
        except KeyError:
            raise KeyError(f"unknown ISAX spec {name!r}; "
                           f"known: {sorted(self._specs)}") from None

    def ops(self) -> list[str]:
        """All dispatch op names in registration order."""
        return list(self._ops)

    def has_op(self, op: str) -> bool:
        """True when some registered spec owns dispatch op ``op``."""
        return op in self._ops

    def op_spec(self, op: str) -> IsaxSpec:
        """Spec owning dispatch op ``op``; ValueError listing the valid ops
        otherwise (the dispatcher's unknown-op error)."""
        try:
            return self._ops[op]
        except KeyError:
            raise ValueError(f"unknown dispatch op {op!r}; "
                             f"known: {sorted(self._ops)}") from None

    def target_isax(self, op: str) -> Optional[str]:
        """ISAX name op is expected to extract, or None (negative control).
        Raises KeyError for unregistered ops (mapping semantics)."""
        if op not in self._ops:
            raise KeyError(op)
        return self._ops[op].target

    def spec_for_kind(self, kind: str) -> IsaxSpec:
        """First spec whose trace kind is ``kind`` (back-compat resolution
        for the old string-keyed ``trace_term`` helper)."""
        for spec in self._specs.values():
            if spec.trace_kind == kind:
                return spec
        raise KeyError(f"no registered spec traces kind {kind!r}")

    # -- derived views ------------------------------------------------------

    def isaxes(self) -> list:
        """The ISAX library: every matchable spec's definition, built once,
        in registration order (the order saturation sees)."""
        if self._isax_cache is None:
            self._isax_cache = [s.isax() for s in self._specs.values()
                                if s.isax is not None]
        return list(self._isax_cache)

    def evaluators(self) -> dict[str, Callable]:
        """ISAX name → numpy evaluator semantics (the table
        ``core/offload.evaluate`` derives its intrinsics from)."""
        return {s.name: s.evaluator for s in self._specs.values()
                if s.evaluator is not None}


# ---------------------------------------------------------------------------
# The global registry (the "aquas.targets" registry of the redesign)
# ---------------------------------------------------------------------------

_REGISTRY = TargetRegistry()
_BUILTINS_LOADED = False


def _load_builtin_domains() -> None:
    """Import-and-register the built-in domains exactly once, in the
    historical library order (llm first, then pointcloud)."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    from repro.targets import llm, pointcloud
    _REGISTRY.register(llm.DOMAIN)
    _REGISTRY.register(pointcloud.DOMAIN)


def default_registry() -> TargetRegistry:
    """The process-wide registry (built-in domains loaded on first use)."""
    _load_builtin_domains()
    return _REGISTRY


def register_domain(package: DomainPackage) -> DomainPackage:
    """Register a new domain package into the global registry (built-ins
    are loaded first, so user domains always append after them)."""
    return default_registry().register(package)
