"""The LLM domain package: every ISAX the language-model vertical ships.

One self-contained module per domain is the point of the registry
redesign: the divergent software trace programs (formerly
``compile/trace.py``), the ISAX skeleton/component definitions and numpy
evaluator semantics (formerly ``core/offload.py``), and the kernel-synth
schedulers (formerly ``compile/dispatch.py``) for flash attention, RMSNorm,
the int8 matvec, the SSD scan, SwiGLU, and the plain-matmul negative
control all live here, assembled into :data:`DOMAIN` and registered by
``repro.targets`` at import time.  The generic dispatch engine never names
any of them.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from repro.core.expr import Term, arr, const, for_, var
from repro.core.kernel_synth import (
    choose_flash_blocks,
    choose_matmul_blocks,
    choose_ssd_blocks,
    pipeline_fields,
)
from repro.core.matching import ISAX
from repro.core.tiling import down_pow2, dtype_itemsize
from repro.kernels import ops as kops
from repro.kernels.pipeline import (
    flash_attention_pipelined,
    int8_matmul_pipelined,
    ssd_scan_pipelined,
)
from repro.targets.registry import ChunkedLowering, DomainPackage, IsaxSpec

if TYPE_CHECKING:
    from repro.compile.trace import OpKey

#: Minimum query rows for the flash ISAX: the row-blocked skeleton needs at
#: least one sublane-worth of rows; single-token decode tiles degenerate.
MIN_QUERY_TILE = 8


# ---------------------------------------------------------------------------
# Trace programs — the *software-side* spellings, deliberately divergent
# from the ISAX semantics so matching is a saturation theorem, not string
# equality (the paper's retargetability claim).
# ---------------------------------------------------------------------------

def _attention_program() -> Term:
    """Row-blocked attention, AF+RF-divergent: the scale rides inside the
    matvec and the softmax omits the max shift (the bench's robustness
    variant) — internal rewrites must recover the flash ISAX form."""
    i = var("i")
    q = ("load", arr("Q"), i)
    s = ("/",
         ("exp", ("matvec", arr("K"), ("*", var("scale"), q))),
         ("rowsum", ("exp", ("matvec", arr("K"), ("*", var("scale"), q)))))
    return for_("i", const(0), var("n_q"), const(1),
                ("store", arr("P"), i, s),
                ("store", arr("O"), i,
                 ("matvec", ("transpose", arr("V")), ("load", arr("P"), i))))


def _rmsnorm_program() -> Term:
    """RMSNorm with rsqrt spelled as recip∘sqrt (RF-divergent)."""
    i = var("i")
    x = ("load", arr("Xn"), i)
    return for_("i", const(0), var("n"), const(1),
                ("store", arr("On"), i,
                 ("*", ("*", x, ("recip", ("sqrt",
                                           ("+", ("rowmean", ("*", x, x)),
                                            var("eps"))))),
                  arr("G"))))


def _matmul_program() -> Term:
    """Plain row-wise matmul — no quantization scale, so it must NOT match
    the int8_matvec ISAX (the library has no bf16 GEMM datapath)."""
    i = var("i")
    return for_("i", const(0), var("n"), const(1),
                ("store", arr("C"), i,
                 ("matvec", arr("W"), ("load", arr("X"), i))))


def _int8_matmul_program() -> Term:
    i = var("i")
    return for_("i", const(0), var("n"), const(1),
                ("store", arr("C"), i,
                 ("*", var("s_w"),
                  ("matvec", arr("Wq"), ("load", arr("X"), i)))))


def _ssd_program() -> Term:
    """SSD recurrence with the loop-carried state dependence through H."""
    t = var("t")
    upd = ("+",
           ("*", ("load", arr("A"), t), ("load", arr("H"), const(0))),
           ("outer", ("load", arr("B"), t), ("load", arr("X"), t)))
    out = ("matvec", ("transpose", ("load", arr("H"), const(0))),
           ("load", arr("C"), t))
    return for_("t", const(0), var("T"), const(1),
                ("store", arr("H"), const(0), upd),
                ("store", arr("Y"), t, out))


# ---------------------------------------------------------------------------
# ISAX definitions: the specialized datapaths this "ASIP" ships (§6
# analogues), written in the same mini-IR as software (§5.1).
# ---------------------------------------------------------------------------

def isax_flash_attention() -> ISAX:
    """Row-blocked attention: for each query row i, S[i] = softmax-numerator,
    O[i] = normalized PV product.  Two components under two store anchors in
    a single-loop skeleton (the paper's Figure 5 shape, adapted)."""
    i = var("i")
    q_row = ("load", arr("Q"), i)
    s_row = ("/",
             ("exp", ("-", ("*", var("scale"), ("matvec", arr("K"), q_row)),
                      ("rowmax", ("*", var("scale"),
                                  ("matvec", arr("K"), q_row))))),
             ("rowsum", ("exp", ("-", ("*", var("scale"),
                                       ("matvec", arr("K"), q_row)),
                                 ("rowmax", ("*", var("scale"),
                                             ("matvec", arr("K"), q_row)))))))
    body_s = ("store", arr("P"), i, s_row)
    body_o = ("store", arr("O"), i,
              ("matvec", ("transpose", arr("V")), ("load", arr("P"), i)))
    term = for_("i", const(0), var("n_q"), const(1), body_s, body_o)
    return ISAX(
        name="flash_attention",
        params=("Q", "K", "V", "scale", "n_q", "P", "O"),
        term=term,
        kernel="flash_attention",
        outputs=("P", "O"),
    )


def isax_int8_matvec() -> ISAX:
    """Quantized GEMV: C[i] = s_w * (Wq @ x[i]) — the LLM-inference ISAX
    (paper §6.5 uses 8-bit quantized Llama attention/FFN)."""
    i = var("i")
    term = for_("i", const(0), var("n"), const(1),
                ("store", arr("C"), i,
                 ("*", var("s_w"),
                  ("matvec", arr("Wq"), ("load", arr("X"), i)))))
    return ISAX(
        name="int8_matvec",
        params=("Wq", "X", "s_w", "n", "C"),
        term=term,
        kernel="int8_matmul",
        outputs=("C",),
    )


def isax_ssd_step() -> ISAX:
    """SSD (state-space duality) recurrence: H ← a_t·H + B_t⊗x_t;
    y_t = H^T·C_t.  Loop-carried dependence through H (tests the §5.4
    loop-carried check)."""
    t = var("t")
    upd = ("+",
           ("*", ("load", arr("A"), t), ("load", arr("H"), const(0))),
           ("outer", ("load", arr("B"), t), ("load", arr("X"), t)))
    out = ("matvec", ("transpose", ("load", arr("H"), const(0))),
           ("load", arr("C"), t))
    term = for_("t", const(0), var("T"), const(1),
                ("store", arr("H"), const(0), upd),
                ("store", arr("Y"), t, out))
    return ISAX(
        name="ssd_step",
        params=("A", "B", "C", "X", "T", "H", "Y"),
        term=term,
        kernel="ssd_scan",
        outputs=("H", "Y"),
    )


def isax_rmsnorm() -> ISAX:
    """Fused RMSNorm row op: O[i] = x * rsqrt(mean(x²) + eps) * g."""
    i = var("i")
    x = ("load", arr("Xn"), i)
    term = for_("i", const(0), var("n"), const(1),
                ("store", arr("On"), i,
                 ("*", ("*", x, ("rsqrt",
                                 ("+", ("rowmean", ("*", x, x)),
                                  var("eps")))),
                  arr("G"))))
    return ISAX(
        name="rmsnorm",
        params=("Xn", "G", "eps", "n", "On"),
        term=term,
        kernel="rmsnorm",
        outputs=("On",),
    )


def isax_swiglu() -> ISAX:
    """Fused SwiGLU MLP row op: O[i] = ((Wg·x)·σ(Wg·x) ⊙ (Wu·x))ᵀ·Wo —
    written with silu expanded to its x·sigmoid(x) = x/(1+exp(−x)) form so
    software variants using either spelling match."""
    i = var("i")
    x = ("load", arr("Xs"), i)
    g = ("matvec", arr("Wg"), x)
    u = ("matvec", arr("Wu"), x)
    silu_g = ("/", g, ("+", ("const:1",), ("exp", ("neg", g))))
    term = for_("i", const(0), var("n"), const(1),
                ("store", arr("Os"), i,
                 ("matvec", ("transpose", arr("Wo")),
                  ("*", silu_g, u))))
    return ISAX(
        name="swiglu",
        params=("Wg", "Wu", "Wo", "Xs", "n", "Os"),
        term=term,
        kernel="swiglu",
        outputs=("Os",),
    )


# ---------------------------------------------------------------------------
# Evaluator semantics (numpy oracles the e-graph evaluator binds;
# kernels/ops.register_kernel_intrinsics overrides them with the
# fused/Pallas-backed datapaths)
# ---------------------------------------------------------------------------

def _np_flash_attention(Q, K, V, scale, n_q, P, O):
    S = (Q @ K.T) * scale
    Pm = np.exp(S - S.max(axis=-1, keepdims=True))
    P[:] = Pm / Pm.sum(axis=-1, keepdims=True)
    O[:] = P @ V


def _np_int8_matvec(Wq, X, s_w, n, C):
    C[:] = (X @ Wq.astype(np.float64).T) * s_w


def _np_ssd_scan(A, B, C, X, T, H, Y):
    h = H[0]
    for t in range(int(T)):
        h = A[t] * h + np.outer(B[t], X[t])
        Y[t] = h.T @ C[t]
    H[0] = h


def _np_rmsnorm(Xn, G, eps, n, On):
    ms = np.mean(Xn * Xn, axis=-1, keepdims=True)
    On[:] = Xn / np.sqrt(ms + eps) * G


def _np_swiglu(Wg, Wu, Wo, Xs, n, Os):
    g = Xs @ Wg.T
    u = Xs @ Wu.T
    Os[:] = (g / (1.0 + np.exp(-g)) * u) @ Wo


# ---------------------------------------------------------------------------
# Schedulers: OpKey → (synthesized schedule dict, "ok") or (None, why-not)
# ---------------------------------------------------------------------------

def _attention_schedule(key: "OpKey"):
    B, S, H, K, T, hd = key.shape
    if S < MIN_QUERY_TILE:
        return None, f"degenerate query tile (S={S} < {MIN_QUERY_TILE})"
    # itemsize (not a name heuristic) so the recorded schedule matches the
    # one the kernel wrapper re-derives from q.dtype.itemsize
    sched = choose_flash_blocks(S, T, hd, dtype_itemsize(key.dtype))
    bq = down_pow2(S, sched.block("q")[0])
    bk = down_pow2(T, sched.block("kv")[0])
    if S % bq or T % bk or H % K:
        return None, f"untileable shape S={S} T={T} H={H} K={K}"
    return ({"block_q": bq, "block_k": bk, "buffering": sched.buffering,
             "est_step_cycles": sched.est_step_cycles,
             "vmem_bytes": sched.vmem_bytes,
             **pipeline_fields(sched)}, "ok")


def _rmsnorm_schedule(key: "OpKey"):
    rows, d = key.shape
    return {"block_rows": down_pow2(rows, 256)}, "ok"


def _int8_matmul_schedule(key: "OpKey"):
    M, Kd, N = key.shape
    sched = choose_matmul_blocks(M, N, Kd, dtype_bytes=1)
    bm = down_pow2(M, sched.block("a")[0])
    bn = down_pow2(N, sched.block("b")[1])
    bk = down_pow2(Kd, sched.block("a")[1])
    if M % bm or N % bn or Kd % bk:
        return None, f"untileable shape M={M} N={N} K={Kd}"
    return ({"block_m": bm, "block_n": bn, "block_k": bk,
             "buffering": sched.buffering, **pipeline_fields(sched)}, "ok")


def _ssd_schedule(key: "OpKey"):
    b, s, H, P, N = key.shape
    sched = choose_ssd_blocks(s, H, P, N)
    chunk = down_pow2(s, sched.block("chunk")[0])
    if s % chunk:
        return None, f"untileable sequence s={s}"
    return ({"chunk": chunk, "buffering": sched.buffering,
             **pipeline_fields(sched)}, "ok")


# ---------------------------------------------------------------------------
# The domain package
# ---------------------------------------------------------------------------

_ATTN_CHUNKED = ChunkedLowering(
    axis=1,
    note="online-softmax chunked XLA lowering",
    fallback_note="single-row query; XLA reference")

DOMAIN = DomainPackage(
    name="llm",
    description="Language-model serving/training hot ops (attention, "
                "RMSNorm, quantized GEMM, SSD scan, SwiGLU).",
    specs=(
        IsaxSpec(
            name="flash_attention",
            isax=isax_flash_attention,
            evaluator=_np_flash_attention,
            trace_kind="attention",
            trace_program=_attention_program,
            ops=("attention", "attention_decode", "attention_paged"),
            rewrites=("softmax-shift", "matvec-scale-right"),
            scheduler=_attention_schedule,
            kernel=kops.flash_attention_gqa,
            kernel_pipelined=flash_attention_pipelined,
            chunked=_ATTN_CHUNKED,
            op_notes=(("attention", "prefill"),
                      ("attention_decode", "1-row query → reference"),
                      ("attention_paged", "1-row query → reference")),
            description="Row-blocked GQA flash attention.",
        ),
        IsaxSpec(
            name="int8_matvec",
            isax=isax_int8_matvec,
            evaluator=_np_int8_matvec,
            trace_kind="int8_matmul",
            trace_program=_int8_matmul_program,
            ops=("int8_matmul",),
            scheduler=_int8_matmul_schedule,
            kernel=kops.int8_matmul,
            kernel_pipelined=int8_matmul_pipelined,
            description="Quantized GEMV/GEMM with per-channel dequant.",
        ),
        IsaxSpec(
            name="ssd_step",
            isax=isax_ssd_step,
            evaluator=_np_ssd_scan,
            trace_kind="ssd_scan",
            trace_program=_ssd_program,
            ops=("ssd_scan",),
            scheduler=_ssd_schedule,
            kernel=kops.ssd_scan,
            kernel_pipelined=ssd_scan_pipelined,
            description="Mamba2 SSD chunked scan (loop-carried state).",
        ),
        IsaxSpec(
            name="rmsnorm",
            isax=isax_rmsnorm,
            evaluator=_np_rmsnorm,
            trace_kind="rmsnorm",
            trace_program=_rmsnorm_program,
            ops=("rmsnorm",),
            rewrites=("rsqrt-form",),
            scheduler=_rmsnorm_schedule,
            kernel=kops.rmsnorm,
            description="Row-blocked fused RMSNorm.",
        ),
        IsaxSpec(
            name="swiglu",
            isax=isax_swiglu,
            evaluator=_np_swiglu,
            rewrites=("div-as-recip-mul",),
            description="Fused SwiGLU MLP row op (library-only: no "
                        "dispatch key yet).",
        ),
        IsaxSpec(
            name="matmul",
            trace_kind="matmul",
            trace_program=_matmul_program,
            ops=("matmul",),
            op_notes=(("matmul", "negative control — no bf16 GEMM "
                                 "datapath exists"),),
            description="Plain bf16/fp32 matmul: deliberate negative "
                        "control that must lower to the XLA reference.",
        ),
    ),
)
