"""``repro.targets`` — the global declarative ISAX/domain registry.

Importing this package loads the built-in ``llm`` and ``pointcloud``
domain packages into the global :class:`TargetRegistry`; the generic
dispatch engine (``repro/compile/dispatch.py``) and the e-graph evaluator
(``repro/core/offload.py``) derive everything — trace programs, the ISAX
library, evaluator intrinsics, schedulers, kernel entry points — from it.

To add a domain, write one module that builds a :class:`DomainPackage`
from :class:`IsaxSpec` entries and call :func:`register_domain` (or
register into your own :class:`TargetRegistry` and thread it through
``LoweringConfig.from_registry`` for isolation).
"""

from repro.targets.registry import (
    ChunkedLowering,
    DomainPackage,
    IsaxSpec,
    TargetRegistry,
    default_registry,
    register_domain,
)

__all__ = [
    "ChunkedLowering",
    "DomainPackage",
    "IsaxSpec",
    "TargetRegistry",
    "default_registry",
    "register_domain",
    "isax_library",
    "evaluators",
]


def isax_library() -> list:
    """The registered ISAX library (registration order) — the canonical
    replacement for the deprecated ``core.offload.isax_library()``."""
    return default_registry().isaxes()


def evaluators() -> dict:
    """ISAX name → numpy evaluator semantics from the global registry."""
    return default_registry().evaluators()


# Load the built-in domains at import time (the declarative-registration
# contract: ``import repro.targets`` is enough to populate the registry).
default_registry()
