"""The point-cloud domain package (the paper's second application domain).

Farthest-point sampling, ball-query grouping, and grouped feature
aggregation — a PointNet++-style set-abstraction stage — as a
self-contained :class:`~repro.targets.registry.DomainPackage`: divergent
trace programs (expanded ‖a‖²+‖b‖²−2ab distance, neg∘colmin∘neg max-pool),
ISAX definitions, numpy evaluator semantics, kernel-synth schedulers, and
the Pallas entry points from ``repro/pointcloud``.  Registered by
``repro.targets`` after the ``llm`` domain; the generic dispatch engine
never imports anything in here by name.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from repro.core.expr import Term, arr, const, for_, var
from repro.core.interface_model import TPU_VMEM_BUDGET
from repro.core.kernel_synth import (
    choose_ball_blocks,
    choose_fps_blocks,
    choose_group_blocks,
    fps_vmem_bytes,
    pipeline_fields,
)
from repro.core.matching import ISAX
from repro.core.tiling import dtype_itemsize
from repro.pointcloud import ops as pcops
from repro.pointcloud.kernels import (
    ball_query_pipelined,
    group_aggregate_pipelined,
)
from repro.targets.registry import DomainPackage, IsaxSpec

if TYPE_CHECKING:
    from repro.compile.trace import OpKey


# ---------------------------------------------------------------------------
# Trace programs (software-side spellings, AF/RF-divergent from the ISAXes)
# ---------------------------------------------------------------------------

def _sqdist_expanded(a, b):
    """Row-wise squared distance in the *expanded* spelling
    ‖a‖² + (‖b‖² − 2·a·b): AF-divergent from the ISAXes' compact
    rowsum((a−b)²) form — ``rewrites.sqdist-expand`` must bridge the gap."""
    return ("+", ("rowsum", ("*", a, a)),
            ("-", ("rowsum", ("*", b, b)),
             ("*", ("const:2",), ("rowsum", ("*", a, b)))))


def _fps_program() -> Term:
    """Farthest-point sampling with the distance spelled expanded; the
    loop-carried dependences (S feeds the same iteration's distance update,
    D feeds the next iteration's argmax) must survive saturation."""
    s = var("s")
    picked = ("load", arr("Xp"), ("load", arr("Sp"), s))
    return for_("s", const(0), var("n_s"), const(1),
                ("store", arr("Sp"), s,
                 ("argmax", ("load", arr("Dp"), const(0)))),
                ("store", arr("Dp"), const(0),
                 ("min", ("load", arr("Dp"), const(0)),
                  _sqdist_expanded(arr("Xp"), picked))))


def _ball_query_program() -> Term:
    """Ball query with the expanded distance spelling (same AF divergence
    as fps, exercised under a different skeleton)."""
    j = var("j")
    return for_("j", const(0), var("n_c"), const(1),
                ("store", arr("Gq"), j,
                 ("ballsel",
                  _sqdist_expanded(arr("Xp"), ("load", arr("Cn"), j)),
                  var("r2"), var("kk"))))


def _group_agg_program() -> Term:
    """Grouped aggregation with max-pool spelled as neg∘colmin∘neg
    (RF-divergent; ``rewrites.colmax-neg-colmin`` recovers the ISAX form)."""
    j = var("j")
    gathered = ("gather", arr("Fg"), ("load", arr("Gq"), j))
    return for_("j", const(0), var("n_c"), const(1),
                ("store", arr("Ag"), j,
                 ("neg", ("colmin", ("neg", gathered)))))


# ---------------------------------------------------------------------------
# ISAX definitions
# ---------------------------------------------------------------------------

def _sqdist(a: Term, b: Term) -> Term:
    """Compact row-wise squared distance ‖a − b‖² (the ISAX-side spelling)."""
    return ("rowsum", ("*", ("-", a, b), ("-", a, b)))


def isax_fps() -> ISAX:
    """Farthest-point sampling: S[s] = argmax of the running min-distance,
    D ← min(D, ‖X − X[S[s]]‖²).  Loop-carried dependences through *both*
    outputs (S feeds the distance update of the same iteration, D feeds the
    argmax of the next) — the point-cloud stress test for the §5.4
    loop-carried checks."""
    s = var("s")
    term = for_("s", const(0), var("n_s"), const(1),
                ("store", arr("Sp"), s,
                 ("argmax", ("load", arr("Dp"), const(0)))),
                ("store", arr("Dp"), const(0),
                 ("min", ("load", arr("Dp"), const(0)),
                  _sqdist(arr("Xp"),
                          ("load", arr("Xp"), ("load", arr("Sp"), s))))))
    return ISAX(
        name="fps",
        params=("Xp", "n_s", "Dp", "Sp"),
        term=term,
        kernel="fps",
        outputs=("Dp", "Sp"),
    )


def isax_ball_query() -> ISAX:
    """Ball query / kNN grouping: G[j] = first-kk indices of X within
    radius² of center j (padded; nearest point when the ball is empty).
    The irregular-gather front half of PointNet++ set abstraction."""
    j = var("j")
    term = for_("j", const(0), var("n_c"), const(1),
                ("store", arr("Gq"), j,
                 ("ballsel",
                  _sqdist(arr("Xp"), ("load", arr("Cn"), j)),
                  var("r2"), var("kk"))))
    return ISAX(
        name="ball_query",
        params=("Xp", "Cn", "r2", "kk", "n_c", "Gq"),
        term=term,
        kernel="ball_query",
        outputs=("Gq",),
    )


def isax_group_agg() -> ISAX:
    """Grouped feature aggregation: A[j] = max-pool over the rows of F
    gathered by neighbor list G[j] (the fused PointNet++ set-abstraction
    datapath: gather + reduce in one pass over the feature array)."""
    j = var("j")
    term = for_("j", const(0), var("n_c"), const(1),
                ("store", arr("Ag"), j,
                 ("colmax", ("gather", arr("Fg"),
                             ("load", arr("Gq"), j)))))
    return ISAX(
        name="group_agg",
        params=("Fg", "Gq", "n_c", "Ag"),
        term=term,
        kernel="group_aggregate",
        outputs=("Ag",),
    )


# ---------------------------------------------------------------------------
# Evaluator semantics (numpy oracles; pointcloud/ops.py's
# register_pointcloud_intrinsics overrides them with the kernel datapaths)
# ---------------------------------------------------------------------------

def _np_fps(Xp, n_s, Dp, Sp):
    d = Dp[0]
    for s in range(int(n_s)):
        Sp[s] = int(np.argmax(d))
        diff = Xp - Xp[Sp[s]]
        d = np.minimum(d, (diff * diff).sum(-1))
    Dp[0] = d


def _np_ball_query(Xp, Cn, r2, kk, n_c, Gq):
    k = int(kk)
    for j in range(int(n_c)):
        diff = Xp - Cn[j]
        d = (diff * diff).sum(-1)
        hits = np.nonzero(d <= float(r2))[0][:k]
        if hits.size == 0:
            Gq[j] = int(np.argmin(d))
        else:
            Gq[j, :hits.size] = hits
            Gq[j, hits.size:] = hits[0]


def _np_group_agg(Fg, Gq, n_c, Ag):
    for j in range(int(n_c)):
        Ag[j] = Fg[np.asarray(Gq[j], np.int64)].max(axis=0)


# ---------------------------------------------------------------------------
# Schedulers
# ---------------------------------------------------------------------------

def _fps_schedule(key: "OpKey"):
    B, N, S = key.shape
    if S > N:
        return None, f"more samples than points (S={S} > N={N})"
    db = dtype_itemsize(key.dtype)
    if fps_vmem_bytes(N, S, db) > TPU_VMEM_BUDGET:
        # FPS has no tiling to shrink — an oversized cloud takes the
        # reference, exactly as the pointcloud/ops wrapper does
        return None, f"point set exceeds VMEM (N={N})"
    sched = choose_fps_blocks(N, S, db)
    return ({"n_points": N, "n_samples": S, "buffering": sched.buffering,
             "vmem_bytes": sched.vmem_bytes,
             **pipeline_fields(sched)}, "ok")


def _ball_schedule(key: "OpKey"):
    B, N, M, K = key.shape
    sched = choose_ball_blocks(M, N, K, dtype_itemsize(key.dtype))
    tiles = pcops.pc_tiles(M, N, sched, "x")
    if tiles is None:
        return None, f"untileable shape M={M} N={N} (pow2 tiles degrade)"
    return ({"block_m": tiles[0], "block_n": tiles[1],
             "buffering": sched.buffering,
             **pipeline_fields(sched)}, "ok")


def _group_schedule(key: "OpKey"):
    B, N, M, K, C = key.shape
    sched = choose_group_blocks(M, N, K, C, dtype_itemsize(key.dtype))
    tiles = pcops.pc_tiles(M, N, sched, "f")
    if tiles is None:
        return None, f"untileable shape M={M} N={N} (pow2 tiles degrade)"
    return ({"block_m": tiles[0], "block_n": tiles[1],
             "buffering": sched.buffering,
             **pipeline_fields(sched)}, "ok")


# ---------------------------------------------------------------------------
# The domain package
# ---------------------------------------------------------------------------

DOMAIN = DomainPackage(
    name="pointcloud",
    description="Point-cloud set abstraction (FPS → ball query → grouped "
                "aggregation), the second application domain.",
    specs=(
        IsaxSpec(
            name="fps",
            isax=isax_fps,
            evaluator=_np_fps,
            trace_kind="fps",
            trace_program=_fps_program,
            ops=("fps",),
            rewrites=("sqdist-expand",),
            scheduler=_fps_schedule,
            kernel=pcops.farthest_point_sample,
            op_notes=(("fps", "loop-carried argmax; never pipelined"),),
            description="Farthest-point sampling (VMEM-resident cloud).",
        ),
        IsaxSpec(
            name="ball_query",
            isax=isax_ball_query,
            evaluator=_np_ball_query,
            trace_kind="ball_query",
            trace_program=_ball_query_program,
            ops=("ball_query",),
            rewrites=("sqdist-expand",),
            scheduler=_ball_schedule,
            kernel=pcops.ball_query,
            kernel_pipelined=ball_query_pipelined,
            description="Radius neighbor grouping over streamed X tiles.",
        ),
        IsaxSpec(
            name="group_agg",
            isax=isax_group_agg,
            evaluator=_np_group_agg,
            trace_kind="group_aggregate",
            trace_program=_group_agg_program,
            ops=("group_aggregate",),
            rewrites=("colmax-neg-colmin",),
            scheduler=_group_schedule,
            kernel=pcops.group_aggregate,
            kernel_pipelined=group_aggregate_pipelined,
            description="Grouped max-pool aggregation "
                        "(gather-as-one-hot-matmul).",
        ),
    ),
)
