"""Step-granular checkpointing with atomic commit and auto-resume.

Layout:
    <dir>/ckpt_<step>.tmp/   — in-progress write (never resumed from)
    <dir>/ckpt_<step>/       — committed (atomic rename)
        manifest.json        — step, leaf paths, shapes/dtypes, config hash
        <leaf-path>.npy      — one file per pytree leaf

Checkpoints are mesh-agnostic: leaves are saved as full (addressable) numpy
arrays and resharded on load against whatever mesh/sharding the restarted job
uses — this is what makes elastic re-scaling work (train on 256 chips,
restart on 512).  Corrupted/partial checkpoints (missing manifest or leaf)
are skipped by ``latest_step``; ``load`` falls back to the newest valid one.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save(directory: str, step: int, tree, extra: dict | None = None) -> str:
    """Write a checkpoint; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"ckpt_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(jax.device_get(tree))
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for path, arr in flat.items():
        arr = np.asarray(arr)
        fname = path.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][path] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "digest": hashlib.sha256(arr.tobytes()).hexdigest()[:16],
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def _valid(path: str) -> bool:
    mf = os.path.join(path, "manifest.json")
    if not os.path.isfile(mf):
        return False
    try:
        with open(mf) as f:
            manifest = json.load(f)
        return all(os.path.isfile(os.path.join(path, meta["file"]))
                   for meta in manifest["leaves"].values())
    except (json.JSONDecodeError, KeyError):
        return False


def steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)", name)
        if m and _valid(os.path.join(directory, name)):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    s = steps(directory)
    return s[-1] if s else None


def load(directory: str, step: int | None = None,
         shardings=None, verify: bool = False):
    """Load a checkpoint (newest valid if step is None).  ``shardings`` — a
    pytree of NamedShardings — reshards leaves onto the current mesh."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint in {directory}")
    path = os.path.join(directory, f"ckpt_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for leaf_path, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(path, meta["file"]))
        if arr.dtype.kind == "V":  # numpy round-trips bf16 etc. as raw void
            import ml_dtypes
            arr = arr.view(np.dtype(meta["dtype"]))
        if verify:
            dig = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
            if dig != meta["digest"]:
                raise IOError(f"digest mismatch for {leaf_path} in {path}")
        flat[leaf_path] = arr
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest


def gc(directory: str, keep: int = 3) -> None:
    """Remove all but the newest ``keep`` checkpoints (and stale .tmp dirs)."""
    for name in os.listdir(directory) if os.path.isdir(directory) else []:
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
    for s in steps(directory)[:-keep]:
        shutil.rmtree(os.path.join(directory, f"ckpt_{s}"),
                      ignore_errors=True)
