"""Trainer: jit'd train step (loss → grads → AdamW), sharded params, gradient
accumulation, checkpointing with auto-resume, straggler monitoring.

Designed so the same code path runs (a) single-CPU smoke tests, (b) the
multi-pod dry-run (via launch/dryrun.py which reuses ``make_train_step``),
and (c) a real cluster.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models.registry import Model, get_model
from repro.optim import schedule as schedules
from repro.optim.adamw import AdamWConfig, apply_updates, init_state
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import FailureInjector, StragglerMonitor


@dataclasses.dataclass
class TrainConfig:
    batch: int = 8
    seq: int = 128
    microbatches: int = 1          # gradient accumulation factor
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    async_ckpt: bool = False       # overlap checkpoint I/O with training
    log_every: int = 10
    seed: int = 0
    warmup: int = 20
    total_steps: int = 1000
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def make_train_step(model: Model, opt_cfg: AdamWConfig, train_cfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With microbatches > 1 the batch leading dim is (n_micro, micro_bsz, ...)
    and gradients accumulate in a lax.scan (bounded live memory)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if train_cfg.microbatches > 1:
            def acc(carry, micro):
                loss_sum, g_sum = carry
                loss, g = jax.value_and_grad(loss_fn)(params, micro)
                return (loss_sum + loss,
                        jax.tree.map(jnp.add, g_sum, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, g_sum), _ = jax.lax.scan(
                acc, (jnp.zeros(()), zeros), batch)
            n = train_cfg.microbatches
            loss = loss_sum / n
            grads = jax.tree.map(lambda g: g / n, g_sum)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr_scale = schedules.warmup_cosine(
            opt_state["step"], warmup=train_cfg.warmup,
            total=train_cfg.total_steps)
        params, opt_state, m = apply_updates(params, grads, opt_state,
                                             opt_cfg, lr_scale)
        m["loss"] = loss
        return params, opt_state, m

    return train_step


class Trainer:
    def __init__(self, model_cfg: ModelConfig, train_cfg: TrainConfig,
                 pipeline_cfg: PipelineConfig = PipelineConfig(),
                 failure_injector: Optional[FailureInjector] = None):
        self.model_cfg = model_cfg
        self.cfg = train_cfg
        self.model = get_model(model_cfg)
        self.pipeline = TokenPipeline(model_cfg, train_cfg.batch,
                                      train_cfg.seq, pipeline_cfg)
        self.monitor = StragglerMonitor()
        self.injector = failure_injector
        self.step = 0
        self.metrics_log: list[dict] = []

        params = self.model.init(jax.random.key(train_cfg.seed))
        opt_state = init_state(params, train_cfg.optimizer)
        # auto-resume from the newest valid checkpoint
        if train_cfg.ckpt_dir and ckpt.latest_step(train_cfg.ckpt_dir) is not None:
            tree, manifest = ckpt.load(train_cfg.ckpt_dir)
            params = jax.tree.map(
                lambda ref, x: jnp.asarray(x, ref.dtype), params,
                tree["params"])
            opt_state = jax.tree.map(
                lambda ref, x: jnp.asarray(x, ref.dtype), opt_state,
                tree["opt_state"])
            self.step = manifest["step"]
        self.params = params
        self.opt_state = opt_state
        self._step_fn = jax.jit(
            make_train_step(self.model, train_cfg.optimizer, train_cfg),
            donate_argnums=(0, 1))
        self._ckpt_thread = None

    def _device_batch(self, step: int) -> dict:
        b = self.pipeline.get_batch(step)
        if self.cfg.microbatches > 1:
            n = self.cfg.microbatches
            b = {k: v.reshape((n, v.shape[0] // n) + v.shape[1:])
                 for k, v in b.items()}
        return jax.tree.map(jnp.asarray, b)

    def train(self, total_steps: int) -> dict:
        last = {}
        while self.step < total_steps:
            t0 = time.perf_counter()
            step = self.step
            if self.injector:
                self.injector.maybe_fail(step)
            batch = self._device_batch(step)
            self.params, self.opt_state, m = self._step_fn(
                self.params, self.opt_state, batch)
            m = {k: float(v) for k, v in m.items()}
            self.step = step + 1
            dt = time.perf_counter() - t0
            ev = self.monitor.record(step, dt)
            m["step_time"] = dt
            if ev is not None:
                m["straggler_z"] = ev.z
            self.metrics_log.append({"step": step, **m})
            last = m
            if (self.cfg.ckpt_dir
                    and self.step % self.cfg.ckpt_every == 0):
                self.save_checkpoint()
        if self.cfg.ckpt_dir:
            self.save_checkpoint()
            self.wait_for_checkpoint()
        return last

    def save_checkpoint(self) -> None:
        """Checkpoint the current state.  With ``async_ckpt`` the device→host
        snapshot happens synchronously (cheap) and the file write runs on a
        background thread, overlapping the next training steps; the previous
        write is joined first so at most one write is in flight."""
        tree = {"params": self.params, "opt_state": self.opt_state}
        extra = {"model": self.model_cfg.name}
        step = self.step
        if not self.cfg.async_ckpt:
            ckpt.save(self.cfg.ckpt_dir, step, tree, extra=extra)
            ckpt.gc(self.cfg.ckpt_dir, keep=self.cfg.keep_ckpts)
            return
        import threading
        self.wait_for_checkpoint()
        snapshot = jax.device_get(tree)  # consistent copy before donation

        def write():
            ckpt.save(self.cfg.ckpt_dir, step, snapshot, extra=extra)
            ckpt.gc(self.cfg.ckpt_dir, keep=self.cfg.keep_ckpts)

        self._ckpt_thread = threading.Thread(target=write, daemon=True)
        self._ckpt_thread.start()

    def wait_for_checkpoint(self) -> None:
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
            self._ckpt_thread = None
