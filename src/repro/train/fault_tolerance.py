"""Fault-tolerance machinery: straggler detection, failure injection, and a
restart supervisor.

At 1000+ nodes the relevant failure modes are (a) hard node loss — handled by
checkpoint/auto-resume (checkpoint.py) plus elastic re-meshing (checkpoints
are mesh-agnostic), and (b) stragglers — detected here by a robust z-score
over recent step wall-times; the report names the slow step so an operator
(or an auto-remediation hook) can drain the offending host.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    median: float
    mad: float
    z: float


class StragglerMonitor:
    """Robust z-score (median/MAD) straggler detector over a sliding window."""

    def __init__(self, window: int = 50, z_threshold: float = 5.0,
                 min_samples: int = 10):
        self.window = window
        self.z_threshold = z_threshold
        self.min_samples = min_samples
        self.times: list[float] = []
        self.events: list[StragglerEvent] = []

    def record(self, step: int, step_time: float) -> Optional[StragglerEvent]:
        history = self.times[-self.window:]
        self.times.append(step_time)
        if len(history) < self.min_samples:
            return None
        med = statistics.median(history)
        mad = statistics.median(abs(t - med) for t in history) or 1e-9
        z = 0.6745 * (step_time - med) / mad
        if z > self.z_threshold:
            ev = StragglerEvent(step, step_time, med, mad, z)
            self.events.append(ev)
            return ev
        return None


class FailureInjector:
    """Deterministic failure injection for tests: raises at given steps."""

    def __init__(self, fail_at: set[int]):
        self.fail_at = set(fail_at)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


def run_with_restarts(make_trainer: Callable[[], "object"],
                      total_steps: int, max_restarts: int = 3) -> "object":
    """Supervisor loop: (re)build the trainer (which auto-resumes from the
    newest checkpoint) and run until total_steps, tolerating up to
    max_restarts failures."""
    restarts = 0
    while True:
        trainer = make_trainer()
        try:
            trainer.train(total_steps)
            return trainer
        except RuntimeError as e:
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded {max_restarts} restarts; last error: {e}")
