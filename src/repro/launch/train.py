"""Training launcher CLI: ``python -m repro.launch.train --arch <id> ...``.

Single-host execution path of the same Trainer the dry-run lowers for the
production mesh.  Reduced configs via --smoke for CPU hosts.
"""

from __future__ import annotations

import argparse

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.optim.adamw import AdamWConfig
from repro.train.fault_tolerance import run_with_restarts
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    tc = TrainConfig(batch=args.batch, seq=args.seq,
                     microbatches=args.microbatches,
                     ckpt_dir=args.ckpt_dir, total_steps=args.steps,
                     optimizer=AdamWConfig(
                         lr=args.lr, compress_grads=args.compress_grads))
    tr = run_with_restarts(lambda: Trainer(cfg, tc), args.steps)
    last = tr.metrics_log[-1]
    print(f"done: step={tr.step} loss={last['loss']:.4f} "
          f"step_time={last['step_time'] * 1e3:.0f}ms")


if __name__ == "__main__":
    main()
