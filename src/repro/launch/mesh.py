"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (256 chips, one v5e pod) or 2×16×16 (512 chips, two pods).

    Axes: 'data' carries DP+FSDP, 'model' carries TP/EP/SP, 'pod' is pure DP
    across pods (gradient all-reduce crosses the DCN/ICI boundary once per
    step; params are not sharded across pods — see DESIGN.md §3.3).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh():
    """Degenerate 1×1 mesh for CPU smoke tests of the sharded code path."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
