"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

MUST be the process entry point (python -m repro.launch.dryrun ...): the
XLA_FLAGS below are read at first jax init, so they are set before ANY other
import, including repro modules that import jax.
"""

# --- these two lines must run before any other import (jax locks device
# --- count on first init) ---------------------------------------------------
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import SHAPES, applicable_shapes    # noqa: E402
from repro.configs.registry import ASSIGNED_ARCHS, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.models.registry import (                         # noqa: E402
    get_model, input_specs, param_specs)
from repro.optim.adamw import AdamWConfig, init_state       # noqa: E402
from repro.roofline.analysis import parse_collectives, roofline  # noqa: E402
from repro.compile import (                                 # noqa: E402
    get_default_backend, set_default_backend)
from repro.models import layers as mlayers                  # noqa: E402
from repro.sharding.policies import (                       # noqa: E402
    activation_specs, batch_sharding, cache_shardings, param_shardings)
from repro.train.trainer import TrainConfig, make_train_step  # noqa: E402


def _opt_cfg(cfg) -> AdamWConfig:
    big = cfg.n_params() > 50e9
    return AdamWConfig(state_dtype="bfloat16" if big else "float32")


def select_policy(cfg, mesh, kind: str, long_context: bool = False) -> str:
    """Arch/phase-aware sharding policy (EXPERIMENTS.md §Perf):

    GQA head_dim TP is a *win* for training when q-heads divide the model
    axis but kv-heads don't (GSPMD gathers the small KV instead of partial-
    summing scores: granite/yi/internlm, +17%); it is a *catastrophe* when
    q-heads don't divide either (arctic: 3×60 GB score all-reduces) and for
    decode (sequence-parallel caches are 33× better)."""
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    if (kind == "decode" and cfg.family not in ("encdec", "hybrid")
            and not long_context):
        # contraction-dim 2-D weight sharding beats FSDP gathers at decode
        # (measured up to 29× incl. seq-parallel KV on internlm/yi, 2.8–3×
        # on paligemma/mamba2/dbrx); encdec, hybrid, and long-context SP
        # cells regress under it (0.4–0.96×) and keep fsdp_tp —
        # EXPERIMENTS.md §Perf addendum.
        return "serve"
    if (kind == "train" and cfg.n_heads and cfg.n_kv_heads
            and cfg.n_heads % model_size == 0
            and cfg.n_kv_heads % model_size != 0):
        return "fsdp_tp_hd"
    return "fsdp_tp"


def build_lowered(arch: str, shape_name: str, mesh,
                  act_sharding: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = get_model(cfg)
    specs = input_specs(cfg, shape)
    pspecs = param_specs(cfg)
    policy = select_policy(cfg, mesh, shape.kind,
                           long_context=shape.name == "long_500k")
    p_shard = param_shardings(cfg, mesh, model.param_axes(), pspecs, policy)
    mlayers.set_activation_shardings(
        activation_specs(cfg, mesh, shape.global_batch)
        if act_sharding else None)
    # (decode under xla_chunked needs no special-casing here anymore: the
    # dispatcher lowers single-row-query attention to the XLA reference,
    # which also avoids the sequence-parallel KV reshape-gather pathology —
    # §Perf granite decode iteration 4.)

    if shape.kind == "train":
        opt_cfg = _opt_cfg(cfg)
        opt_specs = jax.eval_shape(lambda p: init_state(p, opt_cfg), pspecs)
        opt_shard = {
            "step": NamedSharding(mesh, P()),
            "m": p_shard, "v": p_shard,
        }
        if "err" in opt_specs:
            opt_shard["err"] = p_shard
        tc = TrainConfig(total_steps=10_000, warmup=100, optimizer=opt_cfg)
        step = make_train_step(model, opt_cfg, tc)
        b_shard = batch_sharding(cfg, mesh, specs["batch"])
        with mesh:
            jitted = jax.jit(step, in_shardings=(p_shard, opt_shard, b_shard),
                             donate_argnums=(0, 1))
            return jitted.lower(pspecs, opt_specs, specs["batch"])

    if shape.kind == "prefill":
        b_shard = batch_sharding(cfg, mesh, specs["batch"])

        def prefill_fn(params, batch):
            return model.prefill(params, batch)

        with mesh:
            jitted = jax.jit(prefill_fn, in_shardings=(p_shard, b_shard))
            return jitted.lower(pspecs, specs["batch"])

    # decode / serve_step
    tok_shard = batch_sharding(cfg, mesh, {"t": specs["token"]})["t"]
    c_shard = cache_shardings(cfg, mesh, specs["caches"])
    pos_shard = NamedSharding(mesh, P())

    def serve_step(params, token, caches, pos):
        return model.decode_step(params, token, caches, pos)

    with mesh:
        jitted = jax.jit(serve_step,
                         in_shardings=(p_shard, tok_shard, c_shard,
                                       pos_shard),
                         donate_argnums=(2,))
        return jitted.lower(pspecs, specs["token"], specs["caches"],
                            specs["pos"])


def _mem_dict(compiled):
    try:
        m = compiled.memory_analysis()
        return {k: int(getattr(m, k)) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes") if hasattr(m, k)}
    except Exception as e:  # CPU backend may not implement it
        return {"error": str(e)}


def _model_flops(cfg, shape) -> float:
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             skip_existing: bool = True) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    out_path = os.path.join(out_dir, cell_id + ".json")
    if skip_existing and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)
    os.makedirs(out_dir, exist_ok=True)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_chips = 512 if multi_pod else 256
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": n_chips, "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        prior_impl = get_default_backend()
        try:
            lowered = build_lowered(arch, shape_name, mesh)
        finally:
            mlayers.set_activation_shardings(None)
            set_default_backend(prior_impl)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        cost = compiled.cost_analysis() or {}
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and (
                           k in ("flops", "bytes accessed", "transcendentals")
                           or k.startswith("bytes accessed"))}
        rec["memory"] = _mem_dict(compiled)
        hlo = compiled.as_text()
        # collectives inside the layer-scan while body execute n_layers times
        loop_trip = cfg.n_layers if cfg.family != "hybrid" else 1
        coll = parse_collectives(hlo, n_chips, loop_trip=loop_trip)
        rec["collectives"] = {
            "counts": coll.counts,
            "in_loop": coll.in_loop_counts,
            "result_bytes": coll.result_bytes,
            "wire_bytes_per_chip": coll.wire_bytes_per_chip,
        }
        flops_dev = float(cost.get("flops", 0.0))
        bytes_dev = float(cost.get("bytes accessed", 0.0))
        rl = roofline(flops_dev * n_chips, bytes_dev * n_chips,
                      coll.wire_bytes_per_chip, n_chips,
                      model_flops=_model_flops(cfg, shape))
        rec["roofline"] = rl.row()
        rec["lower_s"] = t1 - t0
        rec["compile_s"] = t2 - t1
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = time.time() - t0
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--attn-impl", default="xla",
                    choices=["xla", "xla_chunked"],
                    help="xla_chunked = flash-style online-softmax attention")
    args = ap.parse_args()
    set_default_backend(args.attn_impl)

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([s.name for s in applicable_shapes(cfg)]
                  if args.shape == "all" else [args.shape])
        for shape_name in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape_name, mp, args.out,
                               skip_existing=not args.force)
                tag = "OK " if rec["ok"] else "FAIL"
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
                rl = rec.get("roofline", {})
                print(f"[{tag}] {arch} {shape_name} "
                      f"{'2x16x16' if mp else '16x16'} "
                      f"compile={rec.get('compile_s', 0):.1f}s "
                      f"bottleneck={rl.get('bottleneck', '-')}"
                      + ("" if rec["ok"] else
                         f"  err={rec.get('error', '')[:120]}"),
                      flush=True)
    print(f"done: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
