"""Serving launcher CLI: ``python -m repro.launch.serve --arch <id> ...``.

``--continuous`` drives the paged-KV continuous-batching engine on a mixed-
length Poisson workload; the default drives the static-batch engine on a
uniform batch (the original one-shot demo).
"""

from __future__ import annotations

import argparse

import jax

from repro.compile import VALID_BACKENDS, LoweringConfig
from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.serve.engine import ContinuousEngine, ServeEngine
from repro.serve.scheduler import make_poisson_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--backend", default=None, choices=VALID_BACKENDS,
                    help="kernel lowering backend (default: "
                         "REPRO_ATTENTION_IMPL env or 'xla')")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--int8", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over a Poisson workload")
    ap.add_argument("--requests", type=int, default=16,
                    help="workload size for --continuous")
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    lowering = LoweringConfig.from_registry(backend=args.backend)

    if args.continuous:
        ps = args.page_size
        max_len = max(128, args.prompt_len + args.tokens + 16)
        max_len += (-max_len) % ps
        # Mixed-length workload scaled to the flags: prompts up to
        # --prompt-len, outputs up to --tokens.
        prompt_lens = tuple(sorted({max(4, args.prompt_len // 2),
                                    args.prompt_len}))
        out_lens = tuple(sorted({max(2, args.tokens // 4),
                                 max(2, args.tokens // 2), args.tokens}))
        # Buckets must be page multiples; derive them from the page size so
        # any --page-size works, growing until the largest prompt is covered.
        buckets, m = [], 1
        while ps * m <= max_len:
            buckets.append(ps * m)
            if ps * m >= args.prompt_len:
                break
            m *= 2
        if buckets[-1] < args.prompt_len:
            # Doubling overshot max_len before covering the prompt; a
            # rounded-up page multiple always fits (max_len ≥ prompt+tokens).
            buckets.append(args.prompt_len + (-args.prompt_len) % ps)
        buckets = tuple(buckets)
        eng = ContinuousEngine(cfg, max_batch=args.batch,
                               page_size=ps, max_len=max_len,
                               prompt_buckets=buckets, quantize=args.int8,
                               lowering=lowering)
        reqs = make_poisson_workload(args.requests, rate=2.0, vocab=cfg.vocab,
                                     prompt_lens=prompt_lens,
                                     out_lens=out_lens)
        stats = eng.run(reqs)
        print(f"arch={cfg.name} continuous int8={args.int8} "
              f"requests={stats.n_requests} tokens={stats.total_tokens} "
              f"TTFT={stats.mean_ttft_s * 1e3:.1f}ms "
              f"ITL={stats.mean_itl_s * 1e3:.2f}ms "
              f"({stats.tokens_per_s:.1f} tok/s, "
              f"{stats.decode_steps} decode steps)")
        return

    eng = ServeEngine(cfg, max_len=args.prompt_len + args.tokens + 8,
                      quantize=args.int8, lowering=lowering)
    prompts = jax.random.randint(jax.random.key(0),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    toks, stats = eng.generate({"tokens": prompts}, args.tokens)
    print(f"arch={cfg.name} int8={args.int8} out={toks.shape} "
          f"TTFT={stats.ttft_s * 1e3:.1f}ms ITL={stats.itl_s * 1e3:.2f}ms "
          f"({stats.tokens_per_s:.1f} tok/s)")


if __name__ == "__main__":
    main()
