"""Serving launcher CLI: ``python -m repro.launch.serve --arch <id> ...``."""

from __future__ import annotations

import argparse

import jax

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--int8", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    eng = ServeEngine(cfg, max_len=args.prompt_len + args.tokens + 8,
                      quantize=args.int8)
    prompts = jax.random.randint(jax.random.key(0),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    toks, stats = eng.generate({"tokens": prompts}, args.tokens)
    print(f"arch={cfg.name} int8={args.int8} out={toks.shape} "
          f"TTFT={stats.ttft_s * 1e3:.1f}ms ITL={stats.itl_s * 1e3:.2f}ms "
          f"({stats.tokens_per_s:.1f} tok/s)")


if __name__ == "__main__":
    main()
