"""Perf hillclimb harness: lower a (arch × shape) cell under a named variant
and report its roofline terms — the §Perf iteration loop of EXPERIMENTS.md.

    python -m repro.launch.perf --arch qwen1.5-0.5b --shape train_4k \
        --variant dp_only

Variants:
    baseline      — the paper-faithful fsdp_tp policy (same as dryrun)
    dp_only       — pure 256-way DP (params replicated, batch on both axes)
    fsdp_2d       — params sharded over both mesh axes
    bf16_logits   — logits/loss in bf16 (halves the unembed traffic)
    int8_decode   — int8 weights inside the decode step (halves HBM bytes)
    noremat       — remat off (memory↔compute trade)
    int8_allgather— shard_map DP gradient sync with int8 wire payload
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import SHAPES                     # noqa: E402
from repro.configs.registry import get_config             # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.compile import set_default_backend  # noqa: E402
from repro.models import layers as mlayers                # noqa: E402
from repro.models.registry import (get_model, input_specs,  # noqa: E402
                                   param_specs)
from repro.optim.adamw import AdamWConfig, init_state     # noqa: E402
from repro.roofline.analysis import (parse_collectives,   # noqa: E402
                                     roofline)
from repro.sharding.policies import (activation_specs,    # noqa: E402
                                     batch_sharding, cache_shardings,
                                     param_shardings)
from repro.train.trainer import TrainConfig, make_train_step  # noqa: E402


def _quant_specs(pspecs):
    """ShapeDtypeStructs for an int8-quantized param tree."""
    def q(leaf):
        if len(leaf.shape) >= 2:
            return {"q": jax.ShapeDtypeStruct(leaf.shape, jnp.int8),
                    "scale": jax.ShapeDtypeStruct((), jnp.float32)}
        return leaf
    return jax.tree.map(q, pspecs)


def _quant_shardings(p_shard, pspecs, mesh):
    def q(sh, leaf):
        if len(leaf.shape) >= 2:
            return {"q": sh, "scale": NamedSharding(mesh, P())}
        return sh
    return jax.tree.map(q, p_shard, pspecs)


def _dequant(tree):
    def deq(x):
        if isinstance(x, dict) and "q" in x:
            return x["q"].astype(jnp.bfloat16) * x["scale"].astype(jnp.bfloat16)
        return x
    return jax.tree.map(deq, tree,
                        is_leaf=lambda x: isinstance(x, dict) and "q" in x)


def build_variant(arch: str, shape_name: str, mesh, variant: str):
    """``variant`` is a '+'-separated composition, e.g. 'dp_only+noremat'."""
    parts = set(variant.split("+"))
    cfg = get_config(arch)
    if "noremat" in parts:
        cfg = dataclasses.replace(cfg, remat="none")
    if "fullremat" in parts:
        cfg = dataclasses.replace(cfg, remat="full")
    if "dotsremat" in parts:
        cfg = dataclasses.replace(cfg, remat="dots")
    if "bf16_logits" in parts:
        cfg = dataclasses.replace(cfg, logit_dtype="bfloat16")
    if "moe_grouped" in parts and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch="grouped"))
    shape = SHAPES[shape_name]
    model = get_model(cfg)
    specs = input_specs(cfg, shape)
    pspecs = param_specs(cfg)
    policy = next((p for p in ("dp_only", "fsdp_2d") if p in parts),
                  "fsdp_tp")
    if "flash" in parts:
        # the flash-attention ISAX path (online-softmax chunked attention)
        set_default_backend("xla_chunked")
    variant = ("int8_decode" if "int8_decode" in parts else variant)
    p_shard = param_shardings(cfg, mesh, model.param_axes(), pspecs, policy)
    mlayers.set_activation_shardings(
        activation_specs(cfg, mesh, shape.global_batch, policy))

    big = cfg.n_params() > 50e9
    opt_cfg = AdamWConfig(state_dtype="bfloat16" if big else "float32")

    if shape.kind == "train" and "pp" in parts:
        # GPipe pipeline-parallel backbone over the 'model' axis (16 stages);
        # proves PP lowers/compiles on the production mesh for layer-
        # divisible archs (yi-9b, internlm2: 48 = 16×3).
        mlayers.set_activation_shardings(None)
        from repro.models import transformer as T
        from repro.sharding.pipeline import gpipe
        n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        assert cfg.n_layers % n_stages == 0, (cfg.n_layers, n_stages)
        B, S = shape.global_batch, shape.seq_len
        n_micro = 16
        mb = B // n_micro
        mask = None  # built inside stage_fn (constant-folded)

        def stage_fn(bp, x):
            msk = jnp.tril(jnp.ones((S, S), bool))[None]
            pos = jnp.broadcast_to(jnp.arange(S)[None], (x.shape[0], S))

            def body(h, p):
                h2, _, _ = T.block_fwd(cfg, h, p, msk, pos)
                return h2, None

            h, _ = jax.lax.scan(body, x, bp)
            return h

        pipelined = gpipe(stage_fn, mesh, stage_axis="model",
                          data_axes=("data",))

        def fwd(blocks, x_micro):
            return pipelined(blocks, x_micro)

        bspecs = jax.eval_shape(
            lambda key: jax.vmap(lambda k: __import__(
                "repro.models.transformer", fromlist=["init_block"]
            ).init_block(cfg, k))(jax.random.split(key, cfg.n_layers)),
            jax.random.key(0))
        blk_shard = jax.tree.map(
            lambda l: NamedSharding(mesh, P(*(("model",)
                                              + (None,) * (len(l.shape) - 1)))),
            bspecs)
        x_specs = jax.ShapeDtypeStruct(
            (n_micro, mb, S, cfg.d_model),
            mlayers.dtype_of(cfg.compute_dtype))
        x_shard = NamedSharding(mesh, P(None, "data", None, None))
        with mesh:
            jitted = jax.jit(fwd, in_shardings=(blk_shard, x_shard))
            return cfg, jitted.lower(bspecs, x_specs)

    if shape.kind == "train" and "int8_wire" in parts:
        # shard_map DP step with true int8 gradient wire (replicated params).
        # Inside shard_map everything is device-local — activation sharding
        # constraints (Auto-axis) are meaningless and must be off.
        mlayers.set_activation_shardings(None)
        from repro.optim.wire_compression import (init_err_state,
                                                  make_int8_wire_train_step)
        from repro.sharding.policies import dp_axes as _dpa
        dp = _dpa(mesh, "dp_only")
        step = make_int8_wire_train_step(model, opt_cfg, mesh, dp)
        opt_specs = jax.eval_shape(lambda p: init_state(p, opt_cfg), pspecs)
        err_specs = jax.eval_shape(init_err_state, pspecs)
        rep = NamedSharding(mesh, P())
        p_rep = jax.tree.map(lambda _: rep, p_shard)
        o_rep = jax.tree.map(lambda _: rep, opt_specs)
        b_shard = batch_sharding(cfg, mesh, specs["batch"], "dp_only")
        with mesh:
            jitted = jax.jit(step,
                             in_shardings=(p_rep, o_rep, rep, b_shard),
                             donate_argnums=(0, 1, 2))
            return cfg, jitted.lower(pspecs, opt_specs, err_specs,
                                     specs["batch"])

    if shape.kind == "train":
        opt_specs = jax.eval_shape(lambda p: init_state(p, opt_cfg), pspecs)
        opt_shard = {"step": NamedSharding(mesh, P()), "m": p_shard,
                     "v": p_shard}
        tc = TrainConfig(total_steps=10_000, warmup=100, optimizer=opt_cfg)
        step = make_train_step(model, opt_cfg, tc)
        b_shard = batch_sharding(cfg, mesh, specs["batch"], policy)
        with mesh:
            jitted = jax.jit(step, in_shardings=(p_shard, opt_shard, b_shard),
                             donate_argnums=(0, 1))
            return cfg, jitted.lower(pspecs, opt_specs, specs["batch"])

    if shape.kind == "prefill":
        b_shard = batch_sharding(cfg, mesh, specs["batch"], policy)
        with mesh:
            jitted = jax.jit(lambda p, b: model.prefill(p, b),
                             in_shardings=(p_shard, b_shard))
            return cfg, jitted.lower(pspecs, specs["batch"])

    tok_shard = batch_sharding(cfg, mesh, {"t": specs["token"]}, policy)["t"]
    c_shard = cache_shardings(cfg, mesh, specs["caches"], policy=policy)
    pos_shard = NamedSharding(mesh, P())

    if variant == "int8_decode":
        qspecs = _quant_specs(pspecs)
        q_shard = _quant_shardings(p_shard, pspecs, mesh)

        def serve_step(qparams, token, caches, pos):
            return model.decode_step(_dequant(qparams), token, caches, pos)

        with mesh:
            jitted = jax.jit(serve_step,
                             in_shardings=(q_shard, tok_shard, c_shard,
                                           pos_shard),
                             donate_argnums=(2,))
            return cfg, jitted.lower(qspecs, specs["token"], specs["caches"],
                                     specs["pos"])

    if "int8_kv" in parts and "k" in specs["caches"]:
        # int8 KV cache: halves the dominant decode HBM traffic.  Per-
        # (layer, kv-head) scales; dequant on read, requant on write.
        cs = specs["caches"]
        Lk, Bk, Tk, Kk, hdk = cs["k"].shape
        q_caches = dict(cs)
        q_caches["k"] = jax.ShapeDtypeStruct(cs["k"].shape, jnp.int8)
        q_caches["v"] = jax.ShapeDtypeStruct(cs["v"].shape, jnp.int8)
        q_caches["k_scale"] = jax.ShapeDtypeStruct((Lk, Kk), jnp.float32)
        q_caches["v_scale"] = jax.ShapeDtypeStruct((Lk, Kk), jnp.float32)
        qc_shard = dict(c_shard)
        qc_shard["k_scale"] = NamedSharding(mesh, P())
        qc_shard["v_scale"] = NamedSharding(mesh, P())

        def serve_step(params, token, qcaches, pos):
            sk = qcaches["k_scale"][:, None, None, :, None]
            sv = qcaches["v_scale"][:, None, None, :, None]
            caches = {k2: v2 for k2, v2 in qcaches.items()
                      if k2 not in ("k", "v", "k_scale", "v_scale")}
            caches["k"] = qcaches["k"].astype(jnp.bfloat16) * sk.astype(
                jnp.bfloat16)
            caches["v"] = qcaches["v"].astype(jnp.bfloat16) * sv.astype(
                jnp.bfloat16)
            logits, new = model.decode_step(params, token, caches, pos)
            out = dict(qcaches)
            out["k"] = jnp.clip(jnp.round(new["k"].astype(jnp.float32)
                                          / sk), -127, 127).astype(jnp.int8)
            out["v"] = jnp.clip(jnp.round(new["v"].astype(jnp.float32)
                                          / sv), -127, 127).astype(jnp.int8)
            return logits, out

        with mesh:
            jitted = jax.jit(serve_step,
                             in_shardings=(p_shard, tok_shard, qc_shard,
                                           pos_shard),
                             donate_argnums=(2,))
            return cfg, jitted.lower(pspecs, specs["token"], q_caches,
                                     specs["pos"])

    def serve_step(params, token, caches, pos):
        return model.decode_step(params, token, caches, pos)

    with mesh:
        jitted = jax.jit(serve_step,
                         in_shardings=(p_shard, tok_shard, c_shard,
                                       pos_shard),
                         donate_argnums=(2,))
        return cfg, jitted.lower(pspecs, specs["token"], specs["caches"],
                                 specs["pos"])


def run_variant(arch: str, shape_name: str, variant: str,
                out_dir: str = "runs/perf", multi_pod: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_chips = 512 if multi_pod else 256
    cell = f"{arch}__{shape_name}__{mesh_name}__{variant}"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        try:
            cfg, lowered = build_variant(arch, shape_name, mesh, variant)
        finally:
            mlayers.set_activation_shardings(None)
            set_default_backend("xla")
        compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        loop_trip = cfg.n_layers if cfg.family != "hybrid" else 1
        coll = parse_collectives(hlo, n_chips, loop_trip=loop_trip)
        flops_dev = float(cost.get("flops", 0.0))
        bytes_dev = float(cost.get("bytes accessed", 0.0))
        rl = roofline(flops_dev * n_chips, bytes_dev * n_chips,
                      coll.wire_bytes_per_chip, n_chips)
        rec["roofline"] = rl.row()
        rec["collectives"] = {"counts": coll.counts,
                              "result_bytes": coll.result_bytes,
                              "wire_bytes_per_chip":
                                  coll.wire_bytes_per_chip}
        try:
            m = compiled.memory_analysis()
            rec["memory"] = {
                "argument_size_in_bytes": int(m.argument_size_in_bytes),
                "temp_size_in_bytes": int(m.temp_size_in_bytes)}
        except Exception:
            pass
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    rec["total_s"] = time.time() - t0
    with open(os.path.join(out_dir, cell + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="runs/perf")
    args = ap.parse_args()
    rec = run_variant(args.arch, args.shape, args.variant, args.out,
                      args.multi_pod)
    rl = rec.get("roofline", {})
    print(json.dumps({k: rec.get(k) for k in ("arch", "shape", "variant",
                                              "ok", "error")}, indent=1))
    if rl:
        print(f"compute={rl['compute_s']:.4f}s memory={rl['memory_s']:.4f}s "
              f"collective={rl['collective_s']:.4f}s "
              f"bottleneck={rl['bottleneck']}")


if __name__ == "__main__":
    main()
