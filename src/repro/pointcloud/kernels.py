"""Pallas TPU kernels for the point-cloud vertical.

These are the irregular gather/scatter workloads that motivated the paper's
memory specialization: every op streams a long point/feature array against
a small working set of per-center state.

* **fps** — farthest-point sampling.  Inherently sequential (sample *s+1*'s
  argmax depends on the distance sweep of sample *s*), so the kernel keeps
  the running min-distance in VMEM scratch and walks a ``fori_loop``; there
  is no cross-step transfer to overlap and the synthesis layer never offers
  it a burst pipeline.
* **ball_query** — per-center fixed-radius neighbor selection.  X tiles
  stream over the sequential grid dim while selection state (chosen
  indices, running count, nearest-point fallback) stays warm in scratch;
  the global cumulative rank makes "first k in-radius, ascending" exact
  across tile boundaries.
* **group_aggregate** — gather + max-pool in one pass.  The gather is
  expressed as a one-hot matmul per streamed feature tile (the MXU-friendly
  TPU spelling of a row gather), with a running per-center max in scratch.

``*_pipelined`` variants stream the cold operand (X tiles / feature tiles)
through the explicit burst-DMA pipeline of ``kernels/pipeline.py`` instead
of BlockSpec staging; ``core.kernel_synth`` decides when that pays off.
Everything runs under ``interpret=True`` on CPU — index outputs match the
references exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pipeline import DEFAULT_DEPTH, BurstPipeline

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Farthest-point sampling
# ---------------------------------------------------------------------------

def _fps_kernel(xyz_ref, out_ref, d_scr, *, n_samples: int):
    pts = xyz_ref[0].astype(jnp.float32)               # (N, d)
    d_scr[...] = jnp.full_like(d_scr, 1e30)

    def body(s, last):
        out_ref[0, pl.ds(s, 1)] = jnp.full((1,), last, jnp.int32)
        p = jax.lax.dynamic_slice(pts, (last, 0), (1, pts.shape[1]))
        diff = pts - p
        d = jnp.minimum(d_scr[...], jnp.sum(diff * diff, -1))
        d_scr[...] = d
        return jnp.argmax(d).astype(jnp.int32)

    jax.lax.fori_loop(0, n_samples, body, jnp.int32(0))


def fps(xyz, n_samples: int, *, interpret: bool = False):
    """xyz (B, N, d) float → sampled indices (B, n_samples) int32."""
    B, N, d = xyz.shape
    return pl.pallas_call(
        functools.partial(_fps_kernel, n_samples=n_samples),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, N, d), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, n_samples), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_samples), jnp.int32),
        scratch_shapes=[pltpu.VMEM((N,), jnp.float32)],
        interpret=interpret,
    )(xyz)


# ---------------------------------------------------------------------------
# Ball query (X tiles streamed; selection state warm in scratch)
# ---------------------------------------------------------------------------

def _ball_select_update(x, c, ni, out_ref, sel_scr, cnt_scr, best_scr,
                        bidx_scr, *, r2: float, k: int, block_n: int,
                        n_x: int):
    """One streamed-X-tile update of the per-center selection state.

    Shared by the BlockSpec baseline and the burst-DMA pipelined kernel so
    the rank bookkeeping (exact "first k in-radius, ascending" across tile
    boundaries) lives in one place.  ``x`` (bn, d) f32, ``c`` (bm, d) f32.
    """
    @pl.when(ni == 0)
    def _init():
        sel_scr[...] = jnp.full_like(sel_scr, -1)
        cnt_scr[...] = jnp.zeros_like(cnt_scr)
        best_scr[...] = jnp.full_like(best_scr, 1e30)
        bidx_scr[...] = jnp.zeros_like(bidx_scr)

    diff = c[:, None, :] - x[None, :, :]
    d2 = jnp.sum(diff * diff, -1)                       # (bm, bn)
    mask = d2 <= r2
    base = (ni * block_n).astype(jnp.int32)
    rank = cnt_scr[...][:, None] + jnp.cumsum(mask.astype(jnp.int32), -1)
    ks = jnp.arange(k, dtype=jnp.int32)
    hit = mask[:, None, :] & (rank[:, None, :] == (ks + 1)[None, :, None])
    has = jnp.any(hit, -1)                              # (bm, k)
    idx = base + jnp.argmax(hit, -1).astype(jnp.int32)
    sel_scr[...] = jnp.where(has, idx, sel_scr[...])
    # nearest-point fallback for empty balls (strict < keeps first-occurrence
    # argmin semantics across tiles, matching the reference's global argmin)
    tmin = jnp.min(d2, -1)
    targ = base + jnp.argmin(d2, -1).astype(jnp.int32)
    bidx_scr[...] = jnp.where(tmin < best_scr[...], targ, bidx_scr[...])
    best_scr[...] = jnp.minimum(best_scr[...], tmin)
    cnt_scr[...] = cnt_scr[...] + jnp.sum(mask.astype(jnp.int32), -1)

    @pl.when(ni == n_x - 1)
    def _finalize():
        cnt = cnt_scr[...]
        pad = jnp.where(cnt > 0, sel_scr[:, 0], bidx_scr[...])
        out_ref[0] = jnp.where(cnt[:, None] > ks[None, :],
                               sel_scr[...], pad[:, None])


def _ball_kernel(x_ref, c_ref, out_ref, sel_scr, cnt_scr, best_scr, bidx_scr,
                 *, r2: float, k: int, block_n: int, n_x: int):
    ni = pl.program_id(2)
    _ball_select_update(
        x_ref[0].astype(jnp.float32), c_ref[0].astype(jnp.float32), ni,
        out_ref, sel_scr, cnt_scr, best_scr, bidx_scr,
        r2=r2, k=k, block_n=block_n, n_x=n_x)


def ball_query(xyz, centers, radius: float, k: int, *,
               block_m: int = 32, block_n: int = 256,
               interpret: bool = False, radius_sq: float | None = None):
    """xyz (B, N, d), centers (B, M, d) → neighbor indices (B, M, k) i32.

    ``radius_sq`` overrides ``radius**2`` when the caller holds the squared
    radius exactly (see ``ref.ball_query_ref``).
    """
    B, N, d = xyz.shape
    M = centers.shape[1]
    bm, bn = min(block_m, M), min(block_n, N)
    assert M % bm == 0 and N % bn == 0, (M, bm, N, bn)
    nm, nn = M // bm, N // bn
    r2 = float(radius) ** 2 if radius_sq is None else float(radius_sq)
    return pl.pallas_call(
        functools.partial(_ball_kernel, r2=r2, k=k,
                          block_n=bn, n_x=nn),
        grid=(B, nm, nn),
        in_specs=[
            pl.BlockSpec((1, bn, d), lambda b, mi, ni: (b, ni, 0)),
            pl.BlockSpec((1, bm, d), lambda b, mi, ni: (b, mi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, k), lambda b, mi, ni: (b, mi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M, k), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((bm, k), jnp.int32),
            pltpu.VMEM((bm,), jnp.int32),
            pltpu.VMEM((bm,), jnp.float32),
            pltpu.VMEM((bm,), jnp.int32),
        ],
        interpret=interpret,
    )(xyz, centers)


def _ball_pipelined_kernel(c_ref, x_hbm, out_ref, x_buf, sem,
                           sel_scr, cnt_scr, best_scr, bidx_scr,
                           *, r2: float, k: int, block_n: int, n_x: int,
                           depth: int):
    b, ni = pl.program_id(0), pl.program_id(2)
    pipe = BurstPipeline(
        streams=((lambda t: x_hbm.at[b, pl.ds(t * block_n, block_n), :],
                  x_buf),),
        sem=sem, n_steps=n_x, depth=depth)
    slot = pipe.stream_step(ni)
    _ball_select_update(
        x_buf[slot].astype(jnp.float32), c_ref[0].astype(jnp.float32), ni,
        out_ref, sel_scr, cnt_scr, best_scr, bidx_scr,
        r2=r2, k=k, block_n=block_n, n_x=n_x)


def ball_query_pipelined(xyz, centers, radius: float, k: int, *,
                         block_m: int = 32, block_n: int = 256,
                         depth: int = DEFAULT_DEPTH,
                         interpret: bool = False,
                         radius_sq: float | None = None):
    """Burst-DMA ball query: X tiles streamed HBM→VMEM explicitly."""
    B, N, d = xyz.shape
    M = centers.shape[1]
    bm, bn = min(block_m, M), min(block_n, N)
    assert M % bm == 0 and N % bn == 0, (M, bm, N, bn)
    nm, nn = M // bm, N // bn
    r2 = float(radius) ** 2 if radius_sq is None else float(radius_sq)
    return pl.pallas_call(
        functools.partial(_ball_pipelined_kernel, r2=r2,
                          k=k, block_n=bn, n_x=nn, depth=depth),
        grid=(B, nm, nn),
        in_specs=[
            pl.BlockSpec((1, bm, d), lambda b, mi, ni: (b, mi, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # X stays in HBM
        ],
        out_specs=pl.BlockSpec((1, bm, k), lambda b, mi, ni: (b, mi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M, k), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((depth, bn, d), xyz.dtype),
            pltpu.SemaphoreType.DMA((1, depth)),
            pltpu.VMEM((bm, k), jnp.int32),
            pltpu.VMEM((bm,), jnp.int32),
            pltpu.VMEM((bm,), jnp.float32),
            pltpu.VMEM((bm,), jnp.int32),
        ],
        interpret=interpret,
    )(centers, xyz)


# ---------------------------------------------------------------------------
# Grouped feature aggregation (gather-as-one-hot-matmul + running max)
# ---------------------------------------------------------------------------

def _group_update(f, idx, ni, out_ref, acc_scr, *, block_n: int, n_f: int):
    """One streamed-feature-tile update of the per-center max-pool.

    ``f`` (bn, C) f32 tile of the feature array, ``idx`` (bm, k) i32 global
    neighbor indices.  Rows whose index falls in this tile contribute via a
    one-hot matmul (exact selection); out-of-tile rows are masked to -inf.
    """
    @pl.when(ni == 0)
    def _init():
        acc_scr[...] = jnp.full_like(acc_scr, NEG_INF)

    local = idx - (ni * block_n)                        # (bm, k)
    in_tile = (local >= 0) & (local < block_n)
    onehot = (local[:, :, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, 1, block_n), 2))
    bm, k = idx.shape
    g = jax.lax.dot_general(
        onehot.reshape(bm * k, block_n).astype(jnp.float32), f,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(bm, k, f.shape[1])
    g = jnp.where(in_tile[:, :, None], g, NEG_INF)
    acc_scr[...] = jnp.maximum(acc_scr[...], jnp.max(g, axis=1))

    @pl.when(ni == n_f - 1)
    def _finalize():
        out_ref[0] = acc_scr[...].astype(out_ref.dtype)


def _group_kernel(f_ref, idx_ref, out_ref, acc_scr,
                  *, block_n: int, n_f: int):
    ni = pl.program_id(2)
    _group_update(f_ref[0].astype(jnp.float32), idx_ref[0], ni,
                  out_ref, acc_scr, block_n=block_n, n_f=n_f)


def group_aggregate(features, idx, *, block_m: int = 32, block_n: int = 256,
                    interpret: bool = False):
    """features (B, N, C), idx (B, M, k) i32 → max-pooled (B, M, C)."""
    B, N, C = features.shape
    M, k = idx.shape[1], idx.shape[2]
    bm, bn = min(block_m, M), min(block_n, N)
    assert M % bm == 0 and N % bn == 0, (M, bm, N, bn)
    nm, nn = M // bm, N // bn
    return pl.pallas_call(
        functools.partial(_group_kernel, block_n=bn, n_f=nn),
        grid=(B, nm, nn),
        in_specs=[
            pl.BlockSpec((1, bn, C), lambda b, mi, ni: (b, ni, 0)),
            pl.BlockSpec((1, bm, k), lambda b, mi, ni: (b, mi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, C), lambda b, mi, ni: (b, mi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M, C), features.dtype),
        scratch_shapes=[pltpu.VMEM((bm, C), jnp.float32)],
        interpret=interpret,
    )(features, idx)


def _group_pipelined_kernel(idx_ref, f_hbm, out_ref, f_buf, sem, acc_scr,
                            *, block_n: int, n_f: int, depth: int):
    b, ni = pl.program_id(0), pl.program_id(2)
    pipe = BurstPipeline(
        streams=((lambda t: f_hbm.at[b, pl.ds(t * block_n, block_n), :],
                  f_buf),),
        sem=sem, n_steps=n_f, depth=depth)
    slot = pipe.stream_step(ni)
    _group_update(f_buf[slot].astype(jnp.float32), idx_ref[0], ni,
                  out_ref, acc_scr, block_n=block_n, n_f=n_f)


def group_aggregate_pipelined(features, idx, *, block_m: int = 32,
                              block_n: int = 256,
                              depth: int = DEFAULT_DEPTH,
                              interpret: bool = False):
    """Burst-DMA grouped aggregation: feature tiles streamed HBM→VMEM."""
    B, N, C = features.shape
    M, k = idx.shape[1], idx.shape[2]
    bm, bn = min(block_m, M), min(block_n, N)
    assert M % bm == 0 and N % bn == 0, (M, bm, N, bn)
    nm, nn = M // bm, N // bn
    return pl.pallas_call(
        functools.partial(_group_pipelined_kernel, block_n=bn, n_f=nn,
                          depth=depth),
        grid=(B, nm, nn),
        in_specs=[
            pl.BlockSpec((1, bm, k), lambda b, mi, ni: (b, mi, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # features stay in HBM
        ],
        out_specs=pl.BlockSpec((1, bm, C), lambda b, mi, ni: (b, mi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M, C), features.dtype),
        scratch_shapes=[
            pltpu.VMEM((depth, bn, C), features.dtype),
            pltpu.SemaphoreType.DMA((1, depth)),
            pltpu.VMEM((bm, C), jnp.float32),
        ],
        interpret=interpret,
    )(idx, features)
