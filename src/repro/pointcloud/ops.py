"""Jit-friendly public wrappers around the point-cloud Pallas kernels.

Mirrors ``kernels/ops.py`` for the LLM ops: tile shapes and burst-pipeline
depth come from the interface-aware synthesis flow (``core.kernel_synth``),
shapes the kernels can't tile fall back to the jnp references, and each
wrapper exposes ``interpret=`` so the CPU container executes the real
kernel bodies.  ``pipelined=`` overrides the synthesized go/no-go decision
(None = trust the cost model).

Also registers e-graph intrinsics for the ``fps`` / ``ball_query`` /
``group_agg`` ISAXes so offloaded programs execute through the same
datapaths the "hardware" provides.
"""

from __future__ import annotations

import functools
import os as _os

import jax.numpy as jnp
import numpy as np

from repro.core.interface_model import TPU_VMEM_BUDGET
from repro.core.kernel_synth import (
    choose_ball_blocks,
    choose_fps_blocks,
    choose_group_blocks,
    fps_vmem_bytes,
)
from repro.core.tiling import down_pow2
from repro.kernels.pipeline import use_pipeline
from repro.pointcloud import kernels as pck
from repro.pointcloud import ref as pcref


@functools.lru_cache(maxsize=None)
def _fps_schedule(N: int, S: int, dtype_bytes: int):
    return choose_fps_blocks(N, S, dtype_bytes)


@functools.lru_cache(maxsize=None)
def _ball_schedule(M: int, N: int, k: int, dtype_bytes: int):
    return choose_ball_blocks(M, N, k, dtype_bytes)


@functools.lru_cache(maxsize=None)
def _group_schedule(M: int, N: int, k: int, C: int, dtype_bytes: int):
    return choose_group_blocks(M, N, k, C, dtype_bytes)


def pc_tiles(M: int, N: int, sched, stream_key: str):
    """Derive (bm, bn) power-of-two tiles from a synthesized schedule, or
    None when the shape is untileable.

    ``down_pow2`` always divides, so divisibility can't fail — instead a
    shape with a large odd factor *degrades*: its biggest power-of-two
    divisor collapses toward 1-wide tiles.  Those degenerate launches are
    worse than the XLA reference, so "untileable" means the derived tile
    fell below the meaningful minimum (8 sublanes of centers, 128 lanes of
    streamed rows — or the whole axis when it is smaller than that).
    """
    bm = down_pow2(M, sched.block("centers")[0])
    bn = down_pow2(N, sched.block(stream_key)[0])
    if bm < min(M, 8) or bn < min(N, 128):
        return None
    return bm, bn


def farthest_point_sample(xyz, n_samples: int, *, interpret: bool = False):
    """FPS: xyz (B, N, d) → indices (B, n_samples) i32 (ref fallback when
    asked for more samples than points, or when the cloud exceeds VMEM —
    FPS has no tiling to shrink)."""
    B, N, d = xyz.shape
    if (n_samples > N
            or fps_vmem_bytes(N, n_samples,
                              xyz.dtype.itemsize) > TPU_VMEM_BUDGET):
        return pcref.fps_ref(xyz, n_samples)
    _fps_schedule(N, n_samples, xyz.dtype.itemsize)  # recorded by dispatch
    return pck.fps(xyz, n_samples, interpret=interpret)


def ball_query(xyz, centers, radius: float, k: int, *,
               interpret: bool = False, pipelined: bool | None = None,
               radius_sq: float | None = None):
    """Ball query with synthesis-chosen tiles; ``pipelined`` streams the X
    tiles through the burst-DMA pipeline (None = the cost-model decision).
    ``radius_sq`` supplies the squared radius exactly (the e-graph
    intrinsic's contract is in r² — squaring a rounded sqrt would move the
    boundary by ULPs)."""
    B, N, d = xyz.shape
    M = centers.shape[1]
    sched = _ball_schedule(M, N, k, xyz.dtype.itemsize)
    tiles = pc_tiles(M, N, sched, "x")
    if tiles is None:
        return pcref.ball_query_ref(xyz, centers, radius, k,
                                    radius_sq=radius_sq)
    bm, bn = tiles
    if use_pipeline(sched, pipelined, N // bn):
        return pck.ball_query_pipelined(
            xyz, centers, radius, k, block_m=bm, block_n=bn,
            depth=max(2, sched.buffering), interpret=interpret,
            radius_sq=radius_sq)
    return pck.ball_query(xyz, centers, radius, k, block_m=bm, block_n=bn,
                          interpret=interpret, radius_sq=radius_sq)


def group_aggregate(features, idx, *, interpret: bool = False,
                    pipelined: bool | None = None):
    """Grouped max-pool aggregation with synthesis-chosen tiles;
    ``pipelined`` streams the feature tiles through the burst-DMA pipeline
    (None = the cost-model decision)."""
    B, N, C = features.shape
    M, k = idx.shape[1], idx.shape[2]
    sched = _group_schedule(M, N, k, C, features.dtype.itemsize)
    tiles = pc_tiles(M, N, sched, "f")
    if tiles is None:
        return pcref.group_aggregate_ref(features, idx)
    bm, bn = tiles
    if use_pipeline(sched, pipelined, N // bn):
        return pck.group_aggregate_pipelined(
            features, idx, block_m=bm, block_n=bn,
            depth=max(2, sched.buffering), interpret=interpret)
    return pck.group_aggregate(features, idx, block_m=bm, block_n=bn,
                               interpret=interpret)


# ---------------------------------------------------------------------------
# E-graph intrinsic registration (same pattern as kernels/ops.py: on this
# CPU host the fused path is the jnp oracle — what the hardware datapath
# provides — and REPRO_INTRINSIC_INTERPRET=1 forces the Pallas kernel
# bodies through the interpreter instead).
# ---------------------------------------------------------------------------

_INTERPRET = _os.environ.get("REPRO_INTRINSIC_INTERPRET", "0") == "1"


def _intr_fps(Xp, n_s, Dp, Sp):
    """fps ISAX: valid for the canonical init (Dp uniform → start at 0)."""
    xyz = jnp.asarray(np.asarray(Xp, np.float32))[None]
    if _INTERPRET:
        sel = farthest_point_sample(xyz, int(n_s), interpret=True)
    else:
        sel = pcref.fps_ref(xyz, int(n_s))
    Sp[:] = np.asarray(sel[0], dtype=Sp.dtype)
    # D (the running min-distance) is ISAX-internal state; materialize it
    # for evaluator parity with the reference program.
    d = np.asarray(Dp[0], np.float64)
    X = np.asarray(Xp, np.float64)
    for s in np.asarray(Sp, np.int64):
        diff = X - X[s]
        d = np.minimum(d, (diff * diff).sum(-1))
    Dp[0] = d.astype(Dp.dtype)


def _intr_ball_query(Xp, Cn, r2, kk, n_c, Gq):
    xyz = jnp.asarray(np.asarray(Xp, np.float32))[None]
    cen = jnp.asarray(np.asarray(Cn, np.float32))[None]
    # the ISAX contract is in r²: pass it through exactly (radius_sq) so
    # the in-radius boundary never moves by a sqrt→square round trip
    radius = float(np.sqrt(r2))
    if _INTERPRET:
        sel = ball_query(xyz, cen, radius, int(kk), interpret=True,
                         radius_sq=float(r2))
    else:
        sel = pcref.ball_query_ref(xyz, cen, radius, int(kk),
                                   radius_sq=float(r2))
    Gq[:] = np.asarray(sel[0], dtype=Gq.dtype)


def _intr_group_agg(Fg, Gq, n_c, Ag):
    f = jnp.asarray(np.asarray(Fg, np.float32))[None]
    idx = jnp.asarray(np.asarray(Gq, np.int32))[None]
    if _INTERPRET:
        out = group_aggregate(f, idx, interpret=True)
    else:
        out = pcref.group_aggregate_ref(f, idx)
    Ag[:] = np.asarray(out[0], dtype=Ag.dtype)


def register_pointcloud_intrinsics() -> None:
    """Register the e-graph intrinsics backed by the point-cloud kernels."""
    from repro.core import offload
    offload.register_intrinsic("fps", _intr_fps)
    offload.register_intrinsic("ball_query", _intr_ball_query)
    offload.register_intrinsic("group_agg", _intr_group_agg)
