"""Pure-jnp point-cloud references: the correctness oracles for the Pallas
kernels in ``pointcloud/kernels.py`` (index outputs must match *exactly*;
feature outputs to fp tolerance).

Semantics (shared with the kernels and the e-graph intrinsics):

* ``fps_ref`` starts from index 0 (deterministic, the common convention)
  and computes squared distances in fp32 regardless of input dtype.
* ``ball_query_ref`` returns the first ``k`` in-radius indices per center
  in ascending order, padded with the first hit; a center with an *empty*
  ball gets its nearest point replicated (never an invalid index).
* ``group_aggregate_ref`` max-pools the gathered feature rows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fps_ref(xyz, n_samples: int):
    """Farthest-point sampling: xyz (B, N, d) → indices (B, n_samples) i32."""
    pts = xyz.astype(jnp.float32)
    n = pts.shape[1]

    def one(p):
        def step(carry, _):
            d, last = carry
            diff = p - p[last]
            d = jnp.minimum(d, jnp.sum(diff * diff, -1))
            return (d, jnp.argmax(d).astype(jnp.int32)), last

        init = (jnp.full((n,), 1e30, jnp.float32), jnp.int32(0))
        _, sel = jax.lax.scan(step, init, None, length=n_samples)
        return sel

    return jax.vmap(one)(pts)


def ball_query_ref(xyz, centers, radius: float, k: int,
                   radius_sq: float | None = None):
    """Ball query: xyz (B, N, d), centers (B, M, d) → indices (B, M, k) i32.

    ``radius_sq`` supplies the squared radius exactly when the caller's
    contract is in r² (the e-graph intrinsic) — re-squaring a rounded sqrt
    would move the in-radius boundary by ULPs.
    """
    x = xyz.astype(jnp.float32)
    c = centers.astype(jnp.float32)
    diff = c[:, :, None, :] - x[:, None, :, :]
    d2 = jnp.sum(diff * diff, -1)                       # (B, M, N)
    r2 = (jnp.float32(radius) * jnp.float32(radius)
          if radius_sq is None else jnp.float32(radius_sq))
    mask = d2 <= r2
    rank = jnp.cumsum(mask.astype(jnp.int32), -1)       # (B, M, N)
    count = rank[..., -1]                               # (B, M)
    ks = jnp.arange(k, dtype=jnp.int32)
    hit = mask[:, :, None, :] & (rank[:, :, None, :] == (ks + 1)[:, None])
    sel = jnp.argmax(hit, -1).astype(jnp.int32)         # (B, M, k)
    first = jnp.argmax(mask, -1).astype(jnp.int32)      # first in-radius hit
    nearest = jnp.argmin(d2, -1).astype(jnp.int32)
    pad = jnp.where(count > 0, first, nearest)
    return jnp.where(count[..., None] > ks, sel, pad[..., None])


def group_aggregate_ref(features, idx):
    """Grouped max-pool: features (B, N, C), idx (B, M, k) → (B, M, C)."""
    gathered = jax.vmap(lambda f, i: f[i])(features, idx)  # (B, M, k, C)
    return jnp.max(gathered, axis=2)
