"""Point-cloud processing vertical (the paper's second application domain).

Farthest-point sampling, ball-query neighbor grouping, and grouped feature
aggregation (PointNet++-style set abstraction) ride the same co-design
stack as the LLM ops: ``compile/trace.py`` captures each op as a
``core/expr`` program, the e-graph pipeline matches the ``fps`` /
``ball_query`` / ``group_agg`` ISAXes, ``core/kernel_synth`` schedules the
memory-bound gather against the burst-DMA pipeline, and the Pallas kernels
here execute the result (interpret-mode parity on CPU).
"""

from repro.pointcloud.ops import (
    ball_query,
    farthest_point_sample,
    group_aggregate,
    register_pointcloud_intrinsics,
)
from repro.pointcloud.ref import (
    ball_query_ref,
    fps_ref,
    group_aggregate_ref,
)

__all__ = [
    "ball_query",
    "farthest_point_sample",
    "group_aggregate",
    "register_pointcloud_intrinsics",
    "ball_query_ref",
    "fps_ref",
    "group_aggregate_ref",
]
