"""Deterministic, restart-safe token pipeline.

Batches are pure functions of (seed, step): after a failure+restore at step k
the pipeline resumes producing the exact batch k+1 — no data-order drift
across restarts (the property the fault-tolerance tests assert).

Sources: 'synthetic' (seeded zipf-ish token stream) or a binary token file
(memory-mapped, strided by a per-step permutation).  Host-side numpy; the
trainer device_puts with the activation sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class PipelineConfig:
    seed: int = 0
    source: str = "synthetic"          # 'synthetic' | 'file'
    path: Optional[str] = None
    ignore_id: int = -1


class TokenPipeline:
    def __init__(self, model_cfg: ModelConfig, batch: int, seq: int,
                 cfg: PipelineConfig = PipelineConfig()):
        self.model_cfg = model_cfg
        self.batch = batch
        self.seq = seq
        self.cfg = cfg
        self._file_tokens = None
        if cfg.source == "file":
            assert cfg.path, "file source needs a path"
            self._file_tokens = np.memmap(cfg.path, dtype=np.uint16, mode="r")

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step]))

    def get_batch(self, step: int) -> dict:
        """Returns {'tokens': (B,S) int32, 'labels': (B,S) int32
        [, 'prefix_embeds': (B,P,d) float32]} for train; labels are tokens
        shifted by one."""
        rng = self._rng(step)
        B, S, V = self.batch, self.seq, self.model_cfg.vocab
        if self._file_tokens is not None:
            n = len(self._file_tokens) - (S + 1)
            starts = rng.integers(0, max(n, 1), size=B)
            seqs = np.stack([self._file_tokens[s:s + S + 1] for s in starts])
            seqs = seqs.astype(np.int64) % V
        else:
            # zipf-flavoured synthetic stream (heavier head, long tail)
            seqs = rng.zipf(1.3, size=(B, S + 1)) % V
        tokens = seqs[:, :-1].astype(np.int32)
        labels = seqs[:, 1:].astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        cfg = self.model_cfg
        if cfg.family in ("vlm", "encdec") and cfg.n_prefix_tokens:
            P = cfg.n_prefix_tokens
            out["prefix_embeds"] = rng.normal(
                size=(B, P, cfg.d_model)).astype(np.float32) * 0.02
            if cfg.family == "vlm":
                # text shapes exclude the prefix; shrink token stream
                out["tokens"] = tokens[:, :max(S - P, 1)]
                out["labels"] = labels[:, :max(S - P, 1)]
        return out
