"""Interface-aware synthesis-time optimization (Aquas paper §4.3).

Three progressive passes over Aquas-IR:

  1. **Scratchpad buffer elision** (functional level) — decide whether explicit
     staging buffers can be elided in favour of direct main-memory access.
  2. **Interface selection & canonicalization** (functional → architectural) —
     assign every memory op to exactly one interface by minimizing

         Σ_k T_k  +  Σ_{q,k} X(q,k) · ⌈m_q / C_k⌉ · C_k / W_k

     and greedily split each op into legal transfer sizes (decreasing).
  3. **Transaction scheduling & ordering** (architectural → temporal) — find
     the minimal-latency issue order under the in-flight limit I_k via a
     memoized search whose state is compressed into a relative timing window
     (latency recurrences are insensitive to global time translation), then
     lower to asynchronous issue/wait pairs chained by ``after``.

On TPU the resulting TemporalProgram *is* the hardware description we can
still generate for fixed silicon: a DMA pipeline schedule (see DESIGN.md §3.4)
that ``kernel_synth.py`` converts into Pallas BlockSpec/buffering parameters.
"""

from __future__ import annotations

import functools
import itertools
import math
from typing import Sequence

from repro.core import aquas_ir as ir
from repro.core.interface_model import (
    MemInterface,
    approx_latency,
    cache_sync_penalty,
    sequence_latency,
)

# Exhaustive assignment search is exact up to this many ops per direction;
# beyond it we fall back to greedy + pairwise local search.
_EXACT_ASSIGN_LIMIT = 8
_EXACT_ORDER_LIMIT = 9


# ---------------------------------------------------------------------------
# Pass 1: scratchpad buffer elision (§4.3)
# ---------------------------------------------------------------------------

def _elision_legal(sp: ir.ScratchpadDecl) -> bool:
    """Paper: elision is disabled for scratchpads accessed within unrolled
    regions, outside pipelined loops, or used purely as local temporaries."""
    if sp.accessed_in_unrolled_region:
        return False
    if not sp.inside_pipelined_loop:
        return False
    if sp.purely_local_temp:
        return False
    return True


def _elision_profitable(
    sp: ir.ScratchpadDecl,
    fill_op: ir.FuncOp | None,
    interfaces: dict[str, MemInterface],
) -> bool:
    """Affine analysis + tentative rescheduling check.

    Staged cost   = bulk-transfer latency (fill) + per-element reads are free
                    (on-chip).
    Elided cost   = per-element global accesses; each element's lead-off can
                    hide behind ``compute_cycles_per_elem`` of datapath work,
                    and reuse multiplies traffic.
    Elision also rejected when the reuse factor would thrash the cache
    (reuse > 1 means each global re-read may miss).
    """
    if fill_op is None:
        return False
    if sp.reuse_factor > 1:
        return False  # affine analysis: elision would trigger cache thrashing

    best = min(interfaces.values(), key=lambda k: k.L)
    n_elems = max(1, sp.size_bytes // max(1, sp.elem_bytes))

    # staged: one bulk transfer of the whole buffer on the widest-suitable path
    bulk_itfc = max(interfaces.values(), key=lambda k: k.W * min(k.M, 64))
    bulk_cycles = sequence_latency(
        bulk_itfc, bulk_itfc.decompose(sp.size_bytes), "load")

    # elided: n per-element loads; each hides up to compute_cycles_per_elem
    per_elem = sequence_latency(best, [best.W], "load")
    exposed = max(0.0, per_elem - sp.compute_cycles_per_elem)
    elided_cycles = exposed * n_elems

    return elided_cycles <= bulk_cycles


def elide_scratchpads(
    prog: ir.FunctionalProgram,
    interfaces: dict[str, MemInterface],
) -> tuple[ir.FunctionalProgram, dict[str, str]]:
    """Rewrite read_smem → global fetch for every elidable scratchpad and drop
    the corresponding staging transfer (paper Figure 4(a))."""
    decisions: dict[str, str] = {}
    elided: set[str] = set()
    for name, sp in prog.scratchpads.items():
        fill = next(
            (op for op in prog.ops
             if op.kind == "transfer" and op.dst_space == ir.Space.SCRATCHPAD
             and op.scratchpad == name),
            None,
        )
        if _elision_legal(sp) and _elision_profitable(sp, fill, interfaces):
            elided.add(name)
            decisions[f"scratchpad:{name}"] = "elided"
        else:
            decisions[f"scratchpad:{name}"] = "kept"

    new_ops: list[ir.FuncOp] = []
    for op in prog.ops:
        if op.scratchpad in elided:
            if op.kind == "transfer":
                continue  # staging transfer removed
            if op.kind == "read_smem":
                new_ops.append(ir.FuncOp(
                    kind="fetch", name=op.name, size_bytes=op.size_bytes,
                    src_space=ir.Space.GLOBAL, dst_space=ir.Space.REG,
                    direction="load", cache_hint=op.cache_hint,
                    base_align=op.base_align))
                continue
            if op.kind == "write_smem":
                new_ops.append(ir.FuncOp(
                    kind="transfer", name=op.name, size_bytes=op.size_bytes,
                    src_space=ir.Space.REG, dst_space=ir.Space.GLOBAL,
                    direction="store", cache_hint=op.cache_hint,
                    base_align=op.base_align))
                continue
        new_ops.append(op)

    kept = {n: sp for n, sp in prog.scratchpads.items() if n not in elided}
    return ir.FunctionalProgram(prog.name, new_ops, kept), decisions


# ---------------------------------------------------------------------------
# Pass 2: interface selection & canonicalization (§4.3)
# ---------------------------------------------------------------------------

def _hierarchy_mismatch(op: ir.FuncOp, itfc: MemInterface) -> bool:
    """cache_hint machinery (§4.1): warm data on a DRAM-level interface (or
    cold data on a cache-level interface) incurs synchronization cycles."""
    if op.cache_hint == ir.CacheHint.WARM:
        return itfc.hierarchy_level >= 1
    if op.cache_hint == ir.CacheHint.COLD:
        return itfc.hierarchy_level == 0
    return False


def _objective(
    assign: Sequence[int],
    ops: Sequence[ir.FuncOp],
    itfcs: Sequence[MemInterface],
    direction: str,
) -> float:
    """min Σ_k T_k + Σ_{q,k} X(q,k)·⌈m_q/C_k⌉·C_k/W_k  (cache term applied on
    hierarchy mismatch, per §4.1/§4.3)."""
    total = 0.0
    for ki, itfc in enumerate(itfcs):
        chunks = [itfc.decompose(op.size_bytes)
                  for op, a in zip(ops, assign) if a == ki]
        if chunks:
            total += approx_latency(itfc, chunks, direction)  # T_k
    for op, a in zip(ops, assign):
        itfc = itfcs[a]
        if _hierarchy_mismatch(op, itfc):
            total += cache_sync_penalty(itfc, op.size_bytes)
    return total


def _assign_exact(ops, itfcs, direction):
    best, best_cost = None, math.inf
    for assign in itertools.product(range(len(itfcs)), repeat=len(ops)):
        c = _objective(assign, ops, itfcs, direction)
        if c < best_cost:
            best, best_cost = assign, c
    return list(best), best_cost


def _assign_greedy(ops, itfcs, direction):
    """Greedy seed + pairwise local search for large op counts."""
    assign = []
    for q in range(len(ops)):
        costs = []
        for k in range(len(itfcs)):
            trial = assign + [k] + [0] * (len(ops) - q - 1)
            costs.append(_objective(trial[: q + 1], ops[: q + 1], itfcs, direction))
        assign.append(min(range(len(itfcs)), key=lambda k: costs[k]))
    improved = True
    while improved:
        improved = False
        cur = _objective(assign, ops, itfcs, direction)
        for q in range(len(ops)):
            for k in range(len(itfcs)):
                if k == assign[q]:
                    continue
                trial = list(assign)
                trial[q] = k
                c = _objective(trial, ops, itfcs, direction)
                if c < cur - 1e-9:
                    assign, cur, improved = trial, c, True
    return assign, _objective(assign, ops, itfcs, direction)


def select_interfaces(
    prog: ir.FunctionalProgram,
    interfaces: dict[str, MemInterface],
) -> ir.ArchitecturalProgram:
    """Lower functional memory ops to architectural copy/load ops bound to one
    interface each, canonicalized into legal transfer sequences."""
    itfcs = list(interfaces.values())
    arch_ops: list[ir.ArchOp] = []
    decisions: dict[str, str] = {}

    mem_ops = [op for op in prog.ops
               if op.src_space == ir.Space.GLOBAL or op.dst_space == ir.Space.GLOBAL]
    for direction in ("load", "store"):
        dir_ops = [op for op in mem_ops if op.direction == direction]
        if not dir_ops:
            continue
        if len(dir_ops) <= _EXACT_ASSIGN_LIMIT and len(itfcs) ** len(dir_ops) <= 65536:
            assign, cost = _assign_exact(dir_ops, itfcs, direction)
        else:
            assign, cost = _assign_greedy(dir_ops, itfcs, direction)
        decisions[f"objective:{direction}"] = f"{cost:.1f}"
        for op, ki in zip(dir_ops, assign):
            itfc = itfcs[ki]
            decisions[f"itfc:{op.name}"] = itfc.name
            chunks = itfc.decompose(op.size_bytes, addr=0)
            kind = "copy" if len(chunks) > 1 or chunks[0] > itfc.W else "load"
            for p, m in enumerate(chunks):
                arch_ops.append(ir.ArchOp(
                    kind=kind, name=op.name, size_bytes=m, itfc=itfc,
                    direction=direction, seq_index=p, cache_hint=op.cache_hint))

    return ir.ArchitecturalProgram(prog.name, arch_ops, dict(prog.scratchpads),
                                   decisions)


# ---------------------------------------------------------------------------
# Pass 3: transaction scheduling & ordering (§4.3)
# ---------------------------------------------------------------------------

def _group_key(ops: list[ir.ArchOp], direction: str) -> float:
    """Hierarchy grouping rule: reads — top of hierarchy (level 0) first so
    cold data doesn't evict hot; writes — bottom first so hot data stays."""
    lvl = ops[0].itfc.hierarchy_level
    return lvl if direction == "load" else -lvl


def _order_groups_for_interface(
    itfc: MemInterface,
    groups: list[list[int]],      # groups of sizes; each group stays contiguous
    direction: str,
) -> tuple[list[int], float]:
    """Minimal-latency contiguous-group order on one interface via memoized
    search.  State is compressed to a relative timing window: the recurrences
    only ever look back I transactions, and are translation-invariant, so the
    search key is (frozenset of remaining groups, last-I completion deltas)."""
    n = len(groups)
    if n == 0:
        return [], 0.0

    def run(seq_sizes: list[int]) -> float:
        return float(sequence_latency(itfc, seq_sizes, direction))

    if n <= _EXACT_ORDER_LIMIT:
        best_perm, best_cost = None, math.inf
        # memoized branch & bound over group permutations
        @functools.lru_cache(maxsize=None)
        def dp(remaining: frozenset, window: tuple) -> tuple[float, tuple]:
            if not remaining:
                return (max(window) if window else 0.0, ())
            best = (math.inf, ())
            base = min(window) if window else 0.0
            for gi in remaining:
                sizes = groups[gi]
                # simulate appending this group onto the window
                a_prev = base  # translation-compressed issue reference
                b = list(window)
                a_hist = [a_prev]
                for m in sizes:
                    beats = m / itfc.W
                    b_wait = b[-itfc.I] if len(b) >= itfc.I else -1.0
                    a_j = 1 + max(a_hist[-1], b_wait)
                    if direction == "load":
                        b_j = beats + max(b[-1] if b else -1.0, a_j + itfc.L - 1)
                    else:
                        b_j = beats + itfc.E + max(b[-1] if b else -1.0, a_j - 1)
                    a_hist.append(a_j)
                    b.append(b_j)
                new_window = tuple(b[-itfc.I:])
                # translate so the memo key is relative
                shift = min(new_window)
                key_window = tuple(round(x - shift, 3) for x in new_window)
                sub_cost, sub_order = dp(remaining - {gi}, key_window)
                total = shift + sub_cost
                # note: a_hist translation folded into shift
                if total < best[0]:
                    best = (total, (gi,) + sub_order)
            return best

        # seed window: empty history
        cost, order = dp(frozenset(range(n)), ())
        dp.cache_clear()
        return list(order), cost

    # large: hierarchy-sorted + largest-first heuristic
    order = sorted(range(n), key=lambda gi: (-sum(groups[gi]),))
    flat = [m for gi in order for m in groups[gi]]
    return order, run(flat)


def schedule_transactions(
    arch: ir.ArchitecturalProgram,
) -> ir.TemporalProgram:
    """Lower architectural transfers to ordered asynchronous issue/wait pairs."""
    temporal_ops: list[ir.TemporalOp] = []
    decisions = dict(arch.decisions)
    op_id = 0
    total_cycles = 0.0

    for direction in ("load", "store"):
        # bucket by interface; within an interface, group by originating op
        by_itfc: dict[str, list[ir.ArchOp]] = {}
        for a in arch.ops:
            if a.direction == direction:
                by_itfc.setdefault(a.itfc.name, []).append(a)

        for itfc_name, ops in by_itfc.items():
            itfc = ops[0].itfc
            # contiguity: decomposed segments of one memory op stay together
            by_src: dict[str, list[ir.ArchOp]] = {}
            for a in ops:
                by_src.setdefault(a.name, []).append(a)
            # hierarchy grouping first (stable), then memoized order search
            group_names = sorted(
                by_src.keys(),
                key=lambda nm: _group_key(by_src[nm], direction))
            groups = [[a.size_bytes for a in
                       sorted(by_src[nm], key=lambda a: a.seq_index)]
                      for nm in group_names]
            order, cost = _order_groups_for_interface(itfc, groups, direction)
            decisions[f"order:{itfc_name}:{direction}"] = ",".join(
                group_names[i] for i in order)
            total_cycles = max(total_cycles, cost)

            # emit issue ops chained with `after`, then one wait
            flat: list[tuple[str, int]] = []
            for gi in order:
                for m in groups[gi]:
                    flat.append((group_names[gi], m))
            sizes = [m for _, m in flat]
            # exact per-op timing from the §4.1 recurrences
            a_t = [-1.0]
            b_t = [-1.0]
            prev_id = None
            for j, (nm, m) in enumerate(flat, start=1):
                beats = m / itfc.W
                b_wait = b_t[j - itfc.I] if j - itfc.I >= 1 else -1.0
                a_j = 1 + max(a_t[j - 1], b_wait)
                if direction == "load":
                    b_j = beats + max(b_t[j - 1], a_j + itfc.L - 1)
                else:
                    b_j = beats + itfc.E + max(b_t[j - 1], a_j - 1)
                a_t.append(a_j)
                b_t.append(b_j)
                top = ir.TemporalOp(
                    kind="copy_issue", op_id=op_id, name=nm, size_bytes=m,
                    itfc=itfc, direction=direction, after=prev_id,
                    issue_cycle=a_j, complete_cycle=b_j)
                temporal_ops.append(top)
                prev_id = op_id
                op_id += 1
            if flat:
                temporal_ops.append(ir.TemporalOp(
                    kind="copy_wait", op_id=op_id, name=f"{itfc_name}:{direction}",
                    size_bytes=0, itfc=itfc, direction=direction, after=prev_id,
                    issue_cycle=b_t[-1], complete_cycle=b_t[-1]))
                op_id += 1
                total_cycles = max(total_cycles, b_t[-1])

    return ir.TemporalProgram(arch.name, temporal_ops, total_cycles,
                              dict(arch.scratchpads), decisions)


# ---------------------------------------------------------------------------
# Full pipeline
# ---------------------------------------------------------------------------

def synthesize(
    prog: ir.FunctionalProgram,
    interfaces: dict[str, MemInterface],
) -> ir.TemporalProgram:
    """Functional → Temporal: elision, selection/canonicalization, scheduling."""
    elided, d1 = elide_scratchpads(prog, interfaces)
    arch = select_interfaces(elided, interfaces)
    arch.decisions.update(d1)
    return schedule_transactions(arch)
