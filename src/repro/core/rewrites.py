"""Hybrid rewriting for equivalence-space expansion (paper §5.3).

Two rewrite families applied iteratively to the same e-graph until saturation:

* **Internal rewrites** — dataflow/algebraic rules beneath anchors, expressed
  as fixed egglog-style patterns.  They never touch anchor e-nodes, so control
  flow and side effects are preserved by construction.

* **External rewrites** — control-flow restructurings (loop unrolling, tiling,
  coalescing, re-rolling) that are impractical as local patterns.  Following
  §5.2 ("Reuse MLIR Passes in E-graph"), each is implemented as: extract a
  variant from the e-graph with a cost model, run a conventional AST pass on
  it, re-insert the result, and union it with the original e-class — so pass
  results accumulate non-destructively.

External rewrites are *ISAX-guided*: we compare the software loop structure
with the target ISAX skeleton's loop structure and only trigger transforms
that plausibly converge the two, suppressing e-graph blowup.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import expr
from repro.core.egraph import EGraph, Rewrite, run_rewrites
from repro.core.expr import Term, const, var


# ---------------------------------------------------------------------------
# Internal rewrites (egglog-style fixed rules)
# ---------------------------------------------------------------------------

def _const_of(eg: EGraph, cid: int):
    for node in eg.nodes_of(cid):
        if node[0].startswith("const:"):
            return expr.leaf_value(node[0])
    return None


def _shift_to_mul(eg: EGraph, sub):
    c = _const_of(eg, sub["?c"])
    if isinstance(c, int) and 0 <= c < 31:
        k = eg.add_node(f"const:{2 ** c}", [])
        return eg.add_node("*", [eg.find(sub["?x"]), k])
    return None


def _shr_to_div(eg: EGraph, sub):
    c = _const_of(eg, sub["?c"])
    if isinstance(c, int) and 0 <= c < 31:
        k = eg.add_node(f"const:{2 ** c}", [])
        return eg.add_node("/", [eg.find(sub["?x"]), k])
    return None


def _fold(fn):
    def compute(eg: EGraph, sub):
        a, b = _const_of(eg, sub["?a"]), _const_of(eg, sub["?b"])
        if a is None or b is None:
            return None
        try:
            v = fn(a, b)
        except ZeroDivisionError:
            return None
        if isinstance(v, float) and v.is_integer():
            v = int(v)
        return eg.add_node(f"const:{v}", [])
    return compute


def _div_to_mul_recip(eg: EGraph, sub):
    c = _const_of(eg, sub["?c"])
    if isinstance(c, (int, float)) and c != 0:
        k = eg.add_node(f"const:{1.0 / c}", [])
        return eg.add_node("*", [eg.find(sub["?x"]), k])
    return None


def internal_rules() -> list[Rewrite]:
    a, b, c, x, s = ("?a",), ("?b",), ("?c",), ("?x",), ("?s",)
    R = Rewrite
    return [
        # strength/representation form (RF in Table 3)
        R("shl-to-mul", ("<<", x, c), compute=_shift_to_mul),
        R("shr-to-div", (">>", x, c), compute=_shr_to_div),
        R("div-to-mul-recip", ("/", x, c), compute=_div_to_mul_recip),
        R("sub-to-addneg", ("-", a, b), ("+", a, ("neg", b)),
          bidirectional=True),
        R("relu-to-max", ("relu", x), ("max0", x), bidirectional=True),
        # algebraic form (AF)
        R("add-comm", ("+", a, b), ("+", b, a)),
        R("mul-comm", ("*", a, b), ("*", b, a)),
        R("add-assoc", ("+", ("+", a, b), c), ("+", a, ("+", b, c)),
          bidirectional=True),
        R("mul-assoc", ("*", ("*", a, b), c), ("*", a, ("*", b, c)),
          bidirectional=True),
        R("mul-distrib", ("*", a, ("+", b, c)),
          ("+", ("*", a, b), ("*", a, c)), bidirectional=True),
        # overflow-safe average (paper §6.2: "representation transformations
        # like overflow-safe average")
        R("avg-overflow-safe",
          ("/", ("+", a, b), ("const:2",)),
          ("+", a, ("/", ("-", b, a), ("const:2",))), bidirectional=True),
        # constant folding + identities
        R("fold-add", ("+", a, b), compute=_fold(lambda p, q: p + q)),
        R("fold-mul", ("*", a, b), compute=_fold(lambda p, q: p * q)),
        R("mul-one", ("*", a, ("const:1",)), a),
        R("add-zero", ("+", a, ("const:0",)), a),
        # linear-algebra scaling moves (attention scale placement variants)
        R("matvec-scale-right", ("matvec", a, ("*", s, b)),
          ("*", s, ("matvec", a, b)), bidirectional=True),
        R("matmul-scale-left", ("matmul", ("*", s, a), b),
          ("*", s, ("matmul", a, b)), bidirectional=True),
        # softmax max-shift invariance:
        #   exp(s - rowmax(s)) / rowsum(exp(s - rowmax(s)))
        #     == exp(s) / rowsum(exp(s))
        R("softmax-shift",
          ("/", ("exp", ("-", s, ("rowmax", s))),
                ("rowsum", ("exp", ("-", s, ("rowmax", s))))),
          ("/", ("exp", s), ("rowsum", ("exp", s))), bidirectional=True),
        # squared-distance form: rowsum((a-b)²) == ‖a‖² + (‖b‖² − 2·a·b)
        # (point-cloud software spells the expanded form, the fps/ball_query
        # ISAXes the compact one — this rule is the bridge)
        R("sqdist-expand",
          ("rowsum", ("*", ("-", a, b), ("-", a, b))),
          ("+", ("rowsum", ("*", a, a)),
           ("-", ("rowsum", ("*", b, b)),
            ("*", ("const:2",), ("rowsum", ("*", a, b))))),
          bidirectional=True),
        # max-pool as negated min-pool (representation form: the group_agg
        # software variant spells colmax via neg∘colmin∘neg)
        R("colmax-neg-colmin", ("colmax", x),
          ("neg", ("colmin", ("neg", x))), bidirectional=True),
        # rsqrt form
        R("rsqrt-form", ("rsqrt", x), ("recip", ("sqrt", x)),
          bidirectional=True),
        R("div-as-recip-mul", ("/", a, b), ("*", a, ("recip", b)),
          bidirectional=True),
    ]


def saturate_internal(eg: EGraph, max_iters: int = 6) -> int:
    return run_rewrites(eg, internal_rules(), max_iters=max_iters)


# ---------------------------------------------------------------------------
# External rewrites: loop transformations on extracted terms
# ---------------------------------------------------------------------------

def affine_cost(op: str, child_costs: list[float]) -> float:
    """Extraction cost model of §5.3: a heuristic that penalizes non-affine
    operations (e.g. prefers ``i*4`` over ``i<<2``) so extracted variants are
    oriented toward aggressive loop optimization."""
    if op.startswith("comp:") or op.startswith("isax:"):
        return float("inf")  # markers never appear in a plain variant
    base = 1.0
    if op in ("<<", ">>"):
        base = 50.0  # non-affine in the polyhedral sense
    if op == "while":
        base = 100.0
    return base + sum(child_costs)


def unroll_loop(t: Term, factor: int) -> Optional[Term]:
    """for:i(0,N,s){A} → for:i(0,N,s*f){A[i], A[i+s], …, A[i+(f-1)s]}"""
    if not expr.is_for(t) or factor < 2:
        return None
    idx = expr.for_index(t)
    start, end, step, body = expr.children(t)
    s0, e0, st0 = (expr.const_value(start), expr.const_value(end),
                   expr.const_value(step))
    if None in (s0, e0, st0) or st0 == 0:
        return None
    trip = (e0 - s0) // st0
    if trip % factor != 0:
        return None
    anchors = expr.children(body) if expr.op(body) == "tuple" else (body,)
    new_anchors = []
    for k in range(factor):
        for anc in anchors:
            if k == 0:
                new_anchors.append(anc)
            else:
                new_anchors.append(expr.substitute_var(
                    anc, idx, ("+", var(idx), const(k * st0))))
    return (f"for:{idx}", start, end, const(st0 * factor),
            ("tuple",) + tuple(new_anchors))


def _norm(t: Term) -> Term:
    """Normalization for structural compares: drops +0, folds constant adds,
    and sorts commutative operands so e-graph-generated commuted variants
    compare equal."""
    if expr.is_leaf(t):
        return t
    ch = tuple(_norm(c) for c in expr.children(t))
    o = expr.op(t)
    if o == "+":
        if ch[1] == ("const:0",):
            return ch[0]
        if ch[0] == ("const:0",):
            return ch[1]
        a, b = expr.const_value(ch[0]), expr.const_value(ch[1])
        if a is not None and b is not None:
            return (f"const:{a + b}",)
    if o in expr.COMMUTATIVE:
        ch = tuple(sorted(ch, key=repr))
    return (o,) + ch


def _default_eq(a: Term, b: Term) -> bool:
    return _norm(a) == _norm(b)


def reroll_loop(t: Term, eq=None) -> Optional[Term]:
    """Inverse of unroll: detect f shifted anchor copies, collapse them.

    ``eq(a, b)`` is the term-equality oracle; the external-rewrite driver
    passes equality-modulo-e-graph (two anchors are "the same" if their terms
    land in the same e-class), which tolerates any algebraic divergence the
    internal rules have already proven equivalent.
    """
    eq = eq or _default_eq
    if not expr.is_for(t):
        return None
    idx = expr.for_index(t)
    start, end, step, body = expr.children(t)
    st0 = expr.const_value(step)
    if st0 is None or expr.op(body) != "tuple":
        return None
    anchors = expr.children(body)
    n = len(anchors)
    for f in (8, 4, 2):
        if f > n or n % f or st0 % f:
            continue
        base_step = st0 // f
        group = n // f
        ok = True
        for k in range(1, f):
            for g in range(group):
                expected = expr.substitute_var(
                    anchors[g], idx, ("+", var(idx), const(k * base_step)))
                if not eq(expected, anchors[k * group + g]):
                    ok = False
                    break
            if not ok:
                break
        if ok:
            return (f"for:{idx}", start, end, const(base_step),
                    ("tuple",) + tuple(anchors[:group]))
    return None


def tile_loop(t: Term, factor: int) -> Optional[Term]:
    """for:i(0,N,1){A} → for:i_t(0,N,f){ for:i(i_t, i_t+f, 1){A} }"""
    if not expr.is_for(t) or factor < 2:
        return None
    idx = expr.for_index(t)
    start, end, step, body = expr.children(t)
    s0, e0, st0 = (expr.const_value(start), expr.const_value(end),
                   expr.const_value(step))
    if None in (s0, e0, st0) or st0 != 1 or (e0 - s0) % factor:
        return None
    it = f"{idx}_t"
    inner = (f"for:{idx}", var(it), ("+", var(it), const(factor)),
             const(1), body)
    return (f"for:{it}", start, end, const(factor), ("tuple", inner))


def coalesce_loops(t: Term, eq=None) -> Optional[Term]:
    """Inverse of tile: for:it(0,N,f){ for:i(it, it+f, 1){A} } → for:i(0,N,1){A}"""
    eq = eq or _default_eq
    if not expr.is_for(t):
        return None
    it = expr.for_index(t)
    start, end, step, body = expr.children(t)
    if expr.op(body) != "tuple" or len(expr.children(body)) != 1:
        return None
    inner = expr.children(body)[0]
    if not expr.is_for(inner):
        return None
    i_start, i_end, i_step, i_body = expr.children(inner)
    f = expr.const_value(step)
    if f is None or expr.const_value(i_step) != 1:
        return None
    if not eq(i_start, var(it)):
        return None
    if not eq(i_end, ("+", var(it), const(f))):
        return None
    return (f"for:{expr.for_index(inner)}", start, end, const(1), i_body)


# ---------------------------------------------------------------------------
# ISAX-guided external rewriting driver (§5.3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExternalStats:
    attempted: int = 0
    applied: int = 0
    transforms: list[str] = dataclasses.field(default_factory=list)


def _loops_with_paths(t: Term, path=()) -> list[tuple[tuple, Term]]:
    out = []
    if expr.is_for(t):
        out.append((path, t))
    for i, c in enumerate(expr.children(t)):
        if isinstance(c, tuple):
            out.extend(_loops_with_paths(c, path + (i + 1,)))
    return out


def _replace_at(t: Term, path: tuple, new: Term) -> Term:
    if not path:
        return new
    i = path[0]
    ch = list(t)
    ch[i] = _replace_at(t[i], path[1:], new)
    return tuple(ch)


def structure_distance(sw: tuple | None, hw: tuple | None) -> float:
    """Crude distance between two loop_structure() summaries."""
    if sw is None or hw is None:
        return 0.0 if sw is hw else float("inf")
    d = 0.0
    _, sw_step, sw_nested = sw
    _, hw_step, hw_nested = hw
    if sw_step is not None and hw_step is not None and sw_step != hw_step:
        d += 1.0
    d += 2.0 * abs(len(sw_nested) - len(hw_nested))
    for a2, b2 in zip(sw_nested, hw_nested):
        d += structure_distance(a2, b2)
    return d


def external_rewrite_pass(
    eg: EGraph,
    root: int,
    isax_loop_structure: tuple | None,
    max_rounds: int = 4,
) -> ExternalStats:
    """Extract an affine-friendly variant, apply ISAX-guided loop transforms,
    union results back (non-destructive accumulation per §5.2)."""
    stats = ExternalStats()

    def eg_eq(a: Term, b: Term) -> bool:
        """Equality modulo the e-graph: terms are equal if their classes are
        (or if plain normalization already says so)."""
        if _default_eq(a, b):
            return True
        ia = eg.add_term(expr.normalize_indices(a))
        ib = eg.add_term(expr.normalize_indices(b))
        eg.rebuild()
        return eg.find(ia) == eg.find(ib)

    for _ in range(max_rounds):
        try:
            prog = eg.extract(root, affine_cost)
        except ValueError:
            return stats
        prog = expr.normalize_indices(prog)
        changed = False
        for path, loop in _loops_with_paths(prog):
            sw_struct = expr.loop_structure(loop)
            dist0 = structure_distance(sw_struct, isax_loop_structure)
            if dist0 == 0 or dist0 == float("inf"):
                continue
            candidates: list[tuple[str, Optional[Term]]] = [
                ("coalesce", coalesce_loops(loop, eg_eq)),
                ("reroll", reroll_loop(loop, eg_eq)),
            ]
            if isax_loop_structure is not None:
                _, hw_step, hw_nested = isax_loop_structure
                if hw_nested and hw_nested[0] is not None:
                    # ISAX side is tiled: mirror its tile factor if derivable
                    inner_trip = hw_nested[0][0]
                    if inner_trip:
                        candidates.append(
                            ("tile", tile_loop(loop, inner_trip)))
                if hw_step and hw_step > 1:
                    candidates.append(("unroll", unroll_loop(loop, hw_step)))
            for name, new_loop in candidates:
                stats.attempted += 1
                if new_loop is None:
                    continue
                # NOTE: new_loop keeps in-context index names (outer indices
                # are free vars); alpha-renaming happens on the whole program
                # so nesting-depth names stay collision-free.
                new_struct = expr.loop_structure(new_loop)
                if structure_distance(new_struct, isax_loop_structure) < dist0:
                    new_prog = expr.normalize_indices(
                        _replace_at(prog, path, new_loop))
                    new_root = eg.add_term(new_prog)
                    eg.union(new_root, root)
                    eg.rebuild()
                    stats.applied += 1
                    stats.transforms.append(name)
                    changed = True
                    break
            if changed:
                break
        if not changed:
            break
    return stats
