"""Kernel schedule synthesis: Aquas's interface-aware synthesis applied to
Pallas kernel configuration (the TPU reading of "hardware generation").

For each candidate tiling of a kernel we build the per-grid-step functional
Aquas-IR program (the staging transfers the kernel's DMA pipeline performs),
run the §4.3 synthesis pipeline to get a model-estimated DMA cycle count, add
an MXU/VPU compute estimate, and pick the candidate minimizing the pipelined
steady-state step time:

    step_cycles ≈ max(compute_cycles, dma_cycles / overlap)

where overlap = min(I_hbm, buffering depth).  Constraints: the working set of
`buffering`-deep staging must fit the VMEM budget, and MXU-facing dims must be
multiples of 128 (8 on the sublane axis for f32).

This module is pure Python (no jax) so it can run at trace time.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.core import aquas_ir as ir
from repro.core.interface_model import (
    MXU_DIM,
    TPU_CLOCK_HZ,
    TPU_PEAK_FLOPS_BF16,
    TPU_VMEM_BUDGET,
    MemInterface,
    tpu_interfaces,
)
from repro.core.synthesis import synthesize

# MXU does a 128x128x128 bf16 matmul-accumulate per ~1 cycle equivalent:
_MXU_FLOPS_PER_CYCLE = TPU_PEAK_FLOPS_BF16 / TPU_CLOCK_HZ  # ≈ 123k flops/cycle
_VPU_FLOPS_PER_CYCLE = 8 * 128 * 2  # elementwise lanes


@dataclasses.dataclass(frozen=True)
class KernelSchedule:
    """Synthesized schedule consumed by the Pallas kernels."""

    name: str
    block_shapes: dict[str, tuple[int, ...]]
    buffering: int                 # DMA pipeline depth (in-flight staging)
    est_step_cycles: float
    est_total_cycles: float
    vmem_bytes: int
    decisions: dict[str, str]

    def block(self, key: str) -> tuple[int, ...]:
        return self.block_shapes[key]


def _round_to(x: int, mult: int) -> int:
    return max(mult, (x // mult) * mult)


def _candidate_tiles(dim: int, mult: int, caps: Iterable[int]) -> list[int]:
    out = []
    for c in caps:
        t = min(dim, c)
        t = _round_to(t, mult) if t >= mult else t
        if t > 0 and t not in out:
            out.append(t)
    return out


def _staging_program(
    name: str, transfers: list[tuple[str, int, str]],
) -> ir.FunctionalProgram:
    """Per-grid-step staging as a functional Aquas-IR program.

    transfers: list of (buffer_name, bytes, direction) for one grid step.
    """
    ops = [
        ir.FuncOp(kind="transfer", name=nm, size_bytes=b,
                  src_space=ir.Space.GLOBAL if d == "load" else ir.Space.REG,
                  dst_space=ir.Space.SCRATCHPAD if d == "load" else ir.Space.GLOBAL,
                  direction=d,
                  cache_hint=ir.CacheHint.COLD)  # streamed tiles are cold
        for nm, b, d in transfers
    ]
    return ir.FunctionalProgram(name, ops, {})


def _dma_cycles(name: str, transfers: list[tuple[str, int, str]],
                interfaces: dict[str, MemInterface] | None = None) -> float:
    itfcs = interfaces or {"hbm_vmem": tpu_interfaces()["hbm_vmem"]}
    t = synthesize(_staging_program(name, transfers), itfcs)
    return t.total_cycles


# ---------------------------------------------------------------------------
# Matmul (used by int8_matmul and as the GEMM model for roofline napkin math)
# ---------------------------------------------------------------------------

def choose_matmul_blocks(
    m: int, n: int, k: int,
    dtype_bytes: int = 2,
    acc_bytes: int = 4,
    vmem_budget: int = TPU_VMEM_BUDGET,
) -> KernelSchedule:
    """Pick (bm, bn, bk) + buffering for a tiled GEMM C[m,n] += A[m,k]@B[k,n]."""
    itfc = tpu_interfaces()["hbm_vmem"]
    best: KernelSchedule | None = None
    sub = 8 if dtype_bytes == 4 else 16  # sublane multiple
    for bm in _candidate_tiles(m, sub, (128, 256, 512)):
        for bn in _candidate_tiles(n, MXU_DIM, (128, 256, 512, 1024)):
            for bk in _candidate_tiles(k, MXU_DIM, (128, 256, 512, 1024, 2048)):
                for buf in (2, 3):
                    a_b = bm * bk * dtype_bytes
                    b_b = bk * bn * dtype_bytes
                    c_b = bm * bn * acc_bytes
                    vmem = buf * (a_b + b_b) + c_b
                    if vmem > vmem_budget:
                        continue
                    steps = (math.ceil(m / bm) * math.ceil(n / bn)
                             * math.ceil(k / bk))
                    dma = _dma_cycles("gemm_step",
                                      [("a_tile", a_b, "load"),
                                       ("b_tile", b_b, "load")])
                    compute = 2 * bm * bn * bk / _MXU_FLOPS_PER_CYCLE
                    overlap = min(itfc.I, buf)
                    step = max(compute, dma / overlap)
                    total = step * steps + dma  # + pipeline fill
                    if best is None or total < best.est_total_cycles:
                        best = KernelSchedule(
                            name="matmul",
                            block_shapes={"a": (bm, bk), "b": (bk, bn),
                                          "c": (bm, bn)},
                            buffering=buf,
                            est_step_cycles=step,
                            est_total_cycles=total,
                            vmem_bytes=vmem,
                            decisions={
                                "bound": "compute" if compute >= dma / overlap
                                         else "memory",
                                "steps": str(steps),
                            })
    assert best is not None, "no feasible matmul tiling"
    return best


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

def choose_flash_blocks(
    seq_q: int, seq_k: int, head_dim: int,
    dtype_bytes: int = 2,
    vmem_budget: int = TPU_VMEM_BUDGET,
) -> KernelSchedule:
    """Pick (block_q, block_k) + buffering for the flash-attention ISAX.

    Working set per step: Q tile (persistent across the kv loop — "warm"),
    K/V tiles (streamed — "cold"), running stats, O accumulator.
    """
    best: KernelSchedule | None = None
    hd = max(head_dim, MXU_DIM)  # lane-padded head dim
    for bq in _candidate_tiles(seq_q, 8, (128, 256, 512, 1024)):
        for bk in _candidate_tiles(seq_k, MXU_DIM, (128, 256, 512, 1024)):
            for buf in (2, 3):
                q_b = bq * hd * dtype_bytes
                kv_b = 2 * bk * hd * dtype_bytes
                o_b = bq * hd * 4
                s_b = bq * bk * 4
                vmem = q_b + buf * kv_b + o_b + s_b + bq * 4 * 2
                if vmem > vmem_budget:
                    continue
                kv_steps = math.ceil(seq_k / bk)
                q_steps = math.ceil(seq_q / bq)
                dma = _dma_cycles("flash_step", [("kv_tile", kv_b, "load")])
                flops = 2 * bq * bk * hd * 2 + 5 * bq * bk  # qk + pv + softmax
                compute = (4 * bq * bk * hd / _MXU_FLOPS_PER_CYCLE
                           + 5 * bq * bk / _VPU_FLOPS_PER_CYCLE)
                overlap = min(tpu_interfaces()["hbm_vmem"].I, buf)
                step = max(compute, dma / overlap)
                total = (step * kv_steps + dma) * q_steps
                if best is None or total < best.est_total_cycles:
                    best = KernelSchedule(
                        name="flash_attention",
                        block_shapes={"q": (bq, head_dim), "kv": (bk, head_dim)},
                        buffering=buf,
                        est_step_cycles=step,
                        est_total_cycles=total,
                        vmem_bytes=vmem,
                        decisions={
                            "bound": "compute" if compute >= dma / overlap
                                     else "memory",
                            "kv_steps": str(kv_steps),
                            "q_hint": "warm", "kv_hint": "cold",
                        })
    assert best is not None, "no feasible flash tiling"
    return best


# ---------------------------------------------------------------------------
# Mamba2 SSD chunked scan
# ---------------------------------------------------------------------------

def choose_ssd_blocks(
    seq: int, heads: int, head_dim: int, d_state: int,
    dtype_bytes: int = 2,
    vmem_budget: int = TPU_VMEM_BUDGET,
) -> KernelSchedule:
    """Chunk length for the SSD (state-space duality) chunked scan.

    Per chunk: X, B, C tiles streamed; running state (heads,hd,d_state) warm.
    Intra-chunk cost is quadratic in chunk length (attention-like), state
    update linear — the model balances the two against DMA.
    """
    best: KernelSchedule | None = None
    for chunk in (128, 256, 512):
        if chunk > seq:
            chunk = seq
        for buf in (2, 3):
            x_b = chunk * head_dim * dtype_bytes
            bc_b = 2 * chunk * d_state * dtype_bytes
            state_b = head_dim * d_state * 4
            vmem = buf * (x_b + bc_b) + state_b + chunk * chunk * 4
            if vmem > vmem_budget:
                continue
            steps = math.ceil(seq / chunk)
            dma = _dma_cycles("ssd_step", [("x", x_b, "load"),
                                           ("bc", bc_b, "load")])
            compute = (2 * chunk * chunk * head_dim
                       + 4 * chunk * head_dim * d_state) / _MXU_FLOPS_PER_CYCLE
            overlap = min(tpu_interfaces()["hbm_vmem"].I, buf)
            step = max(compute, dma / overlap)
            total = step * steps + dma
            if best is None or total < best.est_total_cycles:
                best = KernelSchedule(
                    name="ssd_scan",
                    block_shapes={"chunk": (chunk, head_dim),
                                  "state": (head_dim, d_state)},
                    buffering=buf,
                    est_step_cycles=step,
                    est_total_cycles=total,
                    vmem_bytes=vmem,
                    decisions={"bound": "compute" if compute >= dma / overlap
                               else "memory",
                               "chunks": str(steps)})
    assert best is not None, "no feasible ssd tiling"
    return best
