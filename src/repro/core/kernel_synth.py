"""Kernel schedule synthesis: Aquas's interface-aware synthesis applied to
Pallas kernel configuration (the TPU reading of "hardware generation").

For each candidate tiling of a kernel we build the per-grid-step functional
Aquas-IR program (the staging transfers the kernel's DMA pipeline performs),
run the §4.3 synthesis pipeline to get a model-estimated DMA cycle count, add
an MXU/VPU compute estimate, and pick the candidate minimizing the pipelined
steady-state step time:

    step_cycles ≈ max(compute_cycles, dma_cycles / overlap)

where overlap = min(I_hbm, buffering depth).  Constraints: the working set of
`buffering`-deep staging must fit the VMEM budget, and MXU-facing dims must be
multiples of 128 (8 on the sublane axis for f32).

This module is pure Python (no jax) so it can run at trace time.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.core import aquas_ir as ir
from repro.core.interface_model import (
    MXU_DIM,
    TPU_CLOCK_HZ,
    TPU_PEAK_FLOPS_BF16,
    TPU_VMEM_BUDGET,
    MemInterface,
    tpu_interfaces,
)
from repro.core.synthesis import synthesize
from repro.roofline.analysis import pipeline_speedup

# MXU does a 128x128x128 bf16 matmul-accumulate per ~1 cycle equivalent:
_MXU_FLOPS_PER_CYCLE = TPU_PEAK_FLOPS_BF16 / TPU_CLOCK_HZ  # ≈ 123k flops/cycle
_VPU_FLOPS_PER_CYCLE = 8 * 128 * 2  # elementwise lanes

#: Candidate burst-DMA buffer depths; 1 = plain BlockSpec streaming (no
#: manual pipeline), >1 = `kernels/pipeline.py` multi-buffering.
PIPELINE_DEPTHS = (1, 2, 3, 4)

#: Mosaic automatically double-buffers BlockSpec operands across grid
#: steps, so the *baseline* kernel is already overlap-2 — the explicit
#: burst pipeline only wins where deeper staging (up to the interface's
#: in-flight window I) hides more latency than that.  Modeling the
#: baseline as serialized would measure the pipeline against a strawman.
BASELINE_OVERLAP = 2

#: Minimum conservatively-predicted speedup before the burst pipeline is
#: auto-selected — below this the extra VMEM and semaphore traffic isn't
#: worth it, and the kernel runs the plain BlockSpec path.
PIPELINE_GAIN_MIN = 1.02


@dataclasses.dataclass(frozen=True)
class KernelSchedule:
    """Synthesized schedule consumed by the Pallas kernels.

    ``buffering`` is the burst-DMA pipeline depth (1 = plain BlockSpec
    streaming, itself implicitly overlap-2 — see ``BASELINE_OVERLAP``);
    ``pipelined`` is the go/no-go decision after comparing the
    interface-model estimate against that baseline AND the roofline overlap
    bound (the conservative minimum of the two — ``pipeline_gain``).
    ``est_serial_cycles`` is the BlockSpec-baseline cost of the same tiling,
    so consumers can report the predicted win.
    """

    name: str
    block_shapes: dict[str, tuple[int, ...]]
    buffering: int                 # DMA pipeline depth (in-flight staging)
    est_step_cycles: float
    est_total_cycles: float
    vmem_bytes: int
    decisions: dict[str, str]
    pipelined: bool = False
    est_serial_cycles: float = 0.0
    pipeline_gain: float = 1.0

    def block(self, key: str) -> tuple[int, ...]:
        """Tile shape chosen for buffer ``key`` (e.g. ``"kv"``, ``"a"``)."""
        return self.block_shapes[key]


def pipeline_fields(sched: "KernelSchedule") -> dict:
    """Burst-DMA pipeline decision as compile-cache schedule fields.

    Every domain scheduler folds these into the schedule dict it records
    (and therefore into ``BENCH_compile.json`` via ``CompileRecord.row``):
    whether the kernel streams its cold operands through
    ``kernels/pipeline.py`` and the conservatively-predicted gain (the
    depth is the schedule's ``buffering`` field, recorded alongside).
    """
    return {"pipelined": sched.pipelined,
            "pipeline_gain": round(sched.pipeline_gain, 3),
            "est_serial_cycles": sched.est_serial_cycles}


@dataclasses.dataclass(frozen=True)
class _PipeCost:
    """Cost of one (tiling, depth) candidate under the pipeline model."""

    step: float
    total: float
    serial_total: float
    pipelined: bool
    gain: float


def _pipeline_cost(compute: float, dma: float, buf: int, steps: int,
                   flops_per_step: float, bytes_per_step: float,
                   itfc: MemInterface) -> _PipeCost:
    """Burst-pipeline vs BlockSpec-baseline step cost for one candidate.

    depth 1: the BlockSpec baseline — Mosaic's implicit double buffering
    already overlaps at ``BASELINE_OVERLAP``, so a step costs
    ``max(compute, dma / 2)``.  depth > 1: the explicit pipeline keeps up
    to ``min(I, depth)`` copies in flight — ``max(compute, dma/overlap)``.
    Both pay one pipeline-fill DMA per sweep.  The decision gain is the
    *minimum* of the interface-model ratio and the roofline overlap bound
    (``roofline.analysis.pipeline_speedup``), so a predicted loss under
    either model keeps the kernel on the plain path; a depth-2 explicit
    pipeline can never beat the baseline (same overlap), which is exactly
    right — it would replicate what BlockSpec already does.
    """
    base_step = max(compute, dma / min(itfc.I, BASELINE_OVERLAP))
    base_total = base_step * steps + dma
    if buf == 1:
        return _PipeCost(base_step, base_total, base_total, False, 1.0)
    overlap = min(itfc.I, buf)
    step = max(compute, dma / overlap)
    total = step * steps + dma
    gain_model = base_total / total if total > 0 else 1.0
    gain_roofline = pipeline_speedup(flops_per_step * steps,
                                     bytes_per_step * steps)
    gain = min(gain_model, gain_roofline)
    pipelined = steps >= 2 and gain >= PIPELINE_GAIN_MIN
    return _PipeCost(step, total, base_total, pipelined, gain)


def _pipe_note(cost: _PipeCost, buf: int) -> str:
    if not cost.pipelined:
        return "off"
    return f"burst(depth={buf},gain={cost.gain:.2f}x)"


def _round_to(x: int, mult: int) -> int:
    return max(mult, (x // mult) * mult)


def _candidate_tiles(dim: int, mult: int, caps: Iterable[int]) -> list[int]:
    out = []
    for c in caps:
        t = min(dim, c)
        t = _round_to(t, mult) if t >= mult else t
        if t > 0 and t not in out:
            out.append(t)
    return out


def _staging_program(
    name: str, transfers: list[tuple[str, int, str]],
) -> ir.FunctionalProgram:
    """Per-grid-step staging as a functional Aquas-IR program.

    transfers: list of (buffer_name, bytes, direction) for one grid step.
    """
    ops = [
        ir.FuncOp(kind="transfer", name=nm, size_bytes=b,
                  src_space=ir.Space.GLOBAL if d == "load" else ir.Space.REG,
                  dst_space=ir.Space.SCRATCHPAD if d == "load" else ir.Space.GLOBAL,
                  direction=d,
                  cache_hint=ir.CacheHint.COLD)  # streamed tiles are cold
        for nm, b, d in transfers
    ]
    return ir.FunctionalProgram(name, ops, {})


def _dma_cycles(name: str, transfers: list[tuple[str, int, str]],
                interfaces: dict[str, MemInterface] | None = None) -> float:
    itfcs = interfaces or {"hbm_vmem": tpu_interfaces()["hbm_vmem"]}
    t = synthesize(_staging_program(name, transfers), itfcs)
    return t.total_cycles


# ---------------------------------------------------------------------------
# Matmul (used by int8_matmul and as the GEMM model for roofline napkin math)
# ---------------------------------------------------------------------------

def choose_matmul_blocks(
    m: int, n: int, k: int,
    dtype_bytes: int = 2,
    acc_bytes: int = 4,
    vmem_budget: int = TPU_VMEM_BUDGET,
) -> KernelSchedule:
    """Pick (bm, bn, bk) + buffering for a tiled GEMM C[m,n] += A[m,k]@B[k,n]."""
    itfc = tpu_interfaces()["hbm_vmem"]
    best: KernelSchedule | None = None
    sub = 8 if dtype_bytes == 4 else 16  # sublane multiple
    for bm in _candidate_tiles(m, sub, (128, 256, 512)):
        for bn in _candidate_tiles(n, MXU_DIM, (128, 256, 512, 1024)):
            for bk in _candidate_tiles(k, MXU_DIM, (128, 256, 512, 1024, 2048)):
                for buf in PIPELINE_DEPTHS:
                    a_b = bm * bk * dtype_bytes
                    b_b = bk * bn * dtype_bytes
                    c_b = bm * bn * acc_bytes
                    # even the depth-1 baseline holds BASELINE_OVERLAP copies
                    # of each streamed tile (Mosaic double-buffers BlockSpecs)
                    n_bufs = max(buf, BASELINE_OVERLAP)
                    vmem = n_bufs * (a_b + b_b) + c_b
                    if vmem > vmem_budget:
                        continue
                    # The burst pipeline streams within one (mi, ni) k-sweep;
                    # each sweep re-pays the pipeline fill.
                    k_steps = math.ceil(k / bk)
                    mn_sweeps = math.ceil(m / bm) * math.ceil(n / bn)
                    dma = _dma_cycles("gemm_step",
                                      [("a_tile", a_b, "load"),
                                       ("b_tile", b_b, "load")])
                    compute = 2 * bm * bn * bk / _MXU_FLOPS_PER_CYCLE
                    cost = _pipeline_cost(compute, dma, buf, k_steps,
                                          2 * bm * bn * bk, a_b + b_b, itfc)
                    if buf > 1 and not cost.pipelined:
                        continue  # deeper staging predicted not to pay off
                    total = cost.total * mn_sweeps
                    if best is None or total < best.est_total_cycles:
                        best = KernelSchedule(
                            name="matmul",
                            block_shapes={"a": (bm, bk), "b": (bk, bn),
                                          "c": (bm, bn)},
                            buffering=buf,
                            est_step_cycles=cost.step,
                            est_total_cycles=total,
                            vmem_bytes=vmem,
                            decisions={
                                "bound": "compute"
                                         if cost.step <= compute * (1 + 1e-9)
                                         else "memory",
                                "steps": str(k_steps * mn_sweeps),
                                "pipeline": _pipe_note(cost, buf),
                            },
                            pipelined=cost.pipelined,
                            est_serial_cycles=cost.serial_total * mn_sweeps,
                            pipeline_gain=cost.gain)
    assert best is not None, "no feasible matmul tiling"
    return best


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

def choose_flash_blocks(
    seq_q: int, seq_k: int, head_dim: int,
    dtype_bytes: int = 2,
    vmem_budget: int = TPU_VMEM_BUDGET,
) -> KernelSchedule:
    """Pick (block_q, block_k) + buffering for the flash-attention ISAX.

    Working set per step: Q tile (persistent across the kv loop — "warm"),
    K/V tiles (streamed — "cold"), running stats, O accumulator.
    """
    best: KernelSchedule | None = None
    hd = max(head_dim, MXU_DIM)  # lane-padded head dim
    itfc = tpu_interfaces()["hbm_vmem"]
    for bq in _candidate_tiles(seq_q, 8, (128, 256, 512, 1024)):
        for bk in _candidate_tiles(seq_k, MXU_DIM, (128, 256, 512, 1024)):
            for buf in PIPELINE_DEPTHS:
                q_b = bq * hd * dtype_bytes
                kv_b = 2 * bk * hd * dtype_bytes
                o_b = bq * hd * 4
                s_b = bq * bk * 4
                n_bufs = max(buf, BASELINE_OVERLAP)
                vmem = q_b + n_bufs * kv_b + o_b + s_b + bq * 4 * 2
                if vmem > vmem_budget:
                    continue
                kv_steps = math.ceil(seq_k / bk)
                q_steps = math.ceil(seq_q / bq)
                dma = _dma_cycles("flash_step", [("kv_tile", kv_b, "load")])
                flops = 2 * bq * bk * hd * 2 + 5 * bq * bk  # qk + pv + softmax
                compute = (4 * bq * bk * hd / _MXU_FLOPS_PER_CYCLE
                           + 5 * bq * bk / _VPU_FLOPS_PER_CYCLE)
                cost = _pipeline_cost(compute, dma, buf, kv_steps,
                                      flops, kv_b, itfc)
                if buf > 1 and not cost.pipelined:
                    continue
                total = cost.total * q_steps
                if best is None or total < best.est_total_cycles:
                    best = KernelSchedule(
                        name="flash_attention",
                        block_shapes={"q": (bq, head_dim), "kv": (bk, head_dim)},
                        buffering=buf,
                        est_step_cycles=cost.step,
                        est_total_cycles=total,
                        vmem_bytes=vmem,
                        decisions={
                            "bound": "compute"
                                     if cost.step <= compute * (1 + 1e-9)
                                     else "memory",
                            "kv_steps": str(kv_steps),
                            "q_hint": "warm", "kv_hint": "cold",
                            "pipeline": _pipe_note(cost, buf),
                        },
                        pipelined=cost.pipelined,
                        est_serial_cycles=cost.serial_total * q_steps,
                        pipeline_gain=cost.gain)
    assert best is not None, "no feasible flash tiling"
    return best


# ---------------------------------------------------------------------------
# Mamba2 SSD chunked scan
# ---------------------------------------------------------------------------

def choose_ssd_blocks(
    seq: int, heads: int, head_dim: int, d_state: int,
    dtype_bytes: int = 2,
    vmem_budget: int = TPU_VMEM_BUDGET,
) -> KernelSchedule:
    """Chunk length for the SSD (state-space duality) chunked scan.

    Per chunk: X, B, C tiles streamed; running state (heads,hd,d_state) warm.
    Intra-chunk cost is quadratic in chunk length (attention-like), state
    update linear — the model balances the two against DMA.
    """
    best: KernelSchedule | None = None
    itfc = tpu_interfaces()["hbm_vmem"]
    for chunk in (128, 256, 512):
        if chunk > seq:
            chunk = seq
        for buf in PIPELINE_DEPTHS:
            x_b = chunk * head_dim * dtype_bytes
            bc_b = 2 * chunk * d_state * dtype_bytes
            state_b = head_dim * d_state * 4
            n_bufs = max(buf, BASELINE_OVERLAP)
            vmem = n_bufs * (x_b + bc_b) + state_b + chunk * chunk * 4
            if vmem > vmem_budget:
                continue
            steps = math.ceil(seq / chunk)
            dma = _dma_cycles("ssd_step", [("x", x_b, "load"),
                                           ("bc", bc_b, "load")])
            flops = (2 * chunk * chunk * head_dim
                     + 4 * chunk * head_dim * d_state)
            compute = flops / _MXU_FLOPS_PER_CYCLE
            cost = _pipeline_cost(compute, dma, buf, steps,
                                  flops, x_b + bc_b, itfc)
            if buf > 1 and not cost.pipelined:
                continue
            if best is None or cost.total < best.est_total_cycles:
                best = KernelSchedule(
                    name="ssd_scan",
                    block_shapes={"chunk": (chunk, head_dim),
                                  "state": (head_dim, d_state)},
                    buffering=buf,
                    est_step_cycles=cost.step,
                    est_total_cycles=cost.total,
                    vmem_bytes=vmem,
                    decisions={"bound": "compute"
                               if cost.step <= compute * (1 + 1e-9)
                               else "memory",
                               "chunks": str(steps),
                               "pipeline": _pipe_note(cost, buf)},
                    pipelined=cost.pipelined,
                    est_serial_cycles=cost.serial_total,
                    pipeline_gain=cost.gain)
    assert best is not None, "no feasible ssd tiling"
    return best


# ---------------------------------------------------------------------------
# Point-cloud ops (the irregular gather/scatter workloads of the second
# application domain: FPS, ball query, grouped feature aggregation)
# ---------------------------------------------------------------------------

def fps_vmem_bytes(n_pts: int, n_samples: int, dtype_bytes: int = 4) -> int:
    """VMEM working set of the FPS kernel: the whole point set plus the
    running min-distance and the sample indices (FPS has no tiling — a
    cloud that does not fit must take the reference path)."""
    return n_pts * 3 * dtype_bytes + n_pts * 4 + n_samples * 4


def choose_fps_blocks(
    n_pts: int, n_samples: int,
    dtype_bytes: int = 4,
    vmem_budget: int = TPU_VMEM_BUDGET,
) -> KernelSchedule:
    """Farthest-point sampling schedule: the whole point set stays VMEM-
    resident across the sample loop.

    FPS is latency-bound and loop-carried — sample ``s+1``'s argmax depends
    on the distance sweep of sample ``s`` — so there is no cross-step
    transfer to overlap and the burst pipeline is *structurally*
    inapplicable (``buffering=1``, ``pipelined=False`` by construction,
    not a cost-model outcome).

    Callers must pre-check ``fps_vmem_bytes`` (the dispatcher and the op
    wrapper both fall back to the reference when the cloud doesn't fit).
    """
    xyz_b = n_pts * 3 * dtype_bytes
    vmem = fps_vmem_bytes(n_pts, n_samples, dtype_bytes)
    assert vmem <= vmem_budget, f"point set too large for VMEM: {vmem}"
    dma = _dma_cycles("fps_load", [("xyz", xyz_b, "load")])
    # per sample: one (n_pts, 3) diff²-sum sweep + argmax, all on the VPU
    compute = n_samples * (8.0 * n_pts) / _VPU_FLOPS_PER_CYCLE
    total = dma + compute
    return KernelSchedule(
        name="fps",
        block_shapes={"pts": (n_pts, 3)},
        buffering=1,
        est_step_cycles=compute / max(n_samples, 1),
        est_total_cycles=total,
        vmem_bytes=vmem,
        decisions={"bound": "latency", "samples": str(n_samples),
                   "pipeline": "off (loop-carried argmax)"},
        pipelined=False,
        est_serial_cycles=total,
        pipeline_gain=1.0)


def choose_ball_blocks(
    n_centers: int, n_pts: int, k_nb: int,
    dtype_bytes: int = 4,
    vmem_budget: int = TPU_VMEM_BUDGET,
) -> KernelSchedule:
    """Pick (bm centers, bn streamed points) + buffering for ball query.

    Per step: one X coordinate tile streamed (cold), selection state (chosen
    indices, running count/rank, nearest fallback) warm in scratch.  The
    per-point selection math (distance + rank compares against ``k_nb``
    slots) runs on the VPU, so small center tiles are memory-bound and big
    ones compute-bound — the model decides.
    """
    itfc = tpu_interfaces()["hbm_vmem"]
    best: KernelSchedule | None = None
    for bm in _candidate_tiles(n_centers, 8, (8, 16, 32, 64, 128)):
        for bn in _candidate_tiles(n_pts, MXU_DIM, (128, 256, 512, 1024)):
            for buf in PIPELINE_DEPTHS:
                x_b = bn * 3 * dtype_bytes
                state_b = bm * (k_nb + 3) * 4
                n_bufs = max(buf, BASELINE_OVERLAP)
                # per-step intermediates: the (bm, bn) distance tile and the
                # (bm, k, bn) hit tensor the rank selection materializes
                vmem = n_bufs * x_b + bm * 3 * dtype_bytes + state_b \
                    + bm * bn * (1 + k_nb) * 4
                if vmem > vmem_budget:
                    continue
                steps = math.ceil(n_pts / bn)
                m_sweeps = math.ceil(n_centers / bm)
                dma = _dma_cycles("ball_step", [("x_tile", x_b, "load")])
                flops = bm * bn * (8 + k_nb)  # dist² + rank/slot compares
                compute = flops / _VPU_FLOPS_PER_CYCLE
                cost = _pipeline_cost(compute, dma, buf, steps,
                                      flops, x_b, itfc)
                if buf > 1 and not cost.pipelined:
                    continue
                total = cost.total * m_sweeps
                if best is None or total < best.est_total_cycles:
                    best = KernelSchedule(
                        name="ball_query",
                        block_shapes={"centers": (bm, 3), "x": (bn, 3)},
                        buffering=buf,
                        est_step_cycles=cost.step,
                        est_total_cycles=total,
                        vmem_bytes=vmem,
                        decisions={
                            "bound": "compute"
                                     if cost.step <= compute * (1 + 1e-9)
                                     else "memory",
                            "steps": str(steps * m_sweeps),
                            "pipeline": _pipe_note(cost, buf),
                        },
                        pipelined=cost.pipelined,
                        est_serial_cycles=cost.serial_total * m_sweeps,
                        pipeline_gain=cost.gain)
    assert best is not None, "no feasible ball-query tiling"
    return best


def choose_group_blocks(
    n_centers: int, n_pts: int, k_nb: int, channels: int,
    dtype_bytes: int = 4,
    vmem_budget: int = TPU_VMEM_BUDGET,
) -> KernelSchedule:
    """Pick (bm centers, bn streamed feature rows) + buffering for grouped
    feature aggregation (gather-as-one-hot-matmul + running max-pool).

    The streamed feature tile is the cold operand — ``bn * channels`` bytes
    against ``2·bm·k_nb·bn·channels`` MXU flops, so the op is memory-bound
    exactly when ``bm·k_nb`` is small (each feature byte is reused
    ``bm·k_nb`` times): the paper's poster-child shape for the burst DMA
    engine.  Deep staging is auto-selected only on a predicted win (the
    ``_pipeline_cost`` invariant), so compute-bound grouping shapes stay on
    plain BlockSpec streaming.
    """
    itfc = tpu_interfaces()["hbm_vmem"]
    best: KernelSchedule | None = None
    for bm in _candidate_tiles(n_centers, 8, (8, 16, 32, 64, 128)):
        for bn in _candidate_tiles(n_pts, MXU_DIM, (128, 256, 512, 1024)):
            for buf in PIPELINE_DEPTHS:
                f_b = bn * channels * dtype_bytes
                idx_b = bm * k_nb * 4
                acc_b = bm * channels * 4
                n_bufs = max(buf, BASELINE_OVERLAP)
                # per-step intermediates: the (bm·k, bn) one-hot matrix and
                # the (bm, k, channels) gathered tensor — the dominant part
                # of the real working set for large tiles
                vmem = (n_bufs * f_b + idx_b + acc_b
                        + bm * k_nb * bn * 4 + bm * k_nb * channels * 4)
                if vmem > vmem_budget:
                    continue
                steps = math.ceil(n_pts / bn)
                m_sweeps = math.ceil(n_centers / bm)
                dma = _dma_cycles("group_step", [("f_tile", f_b, "load")])
                flops = 2 * bm * k_nb * bn * channels
                compute = (flops / _MXU_FLOPS_PER_CYCLE
                           + bm * k_nb * bn / _VPU_FLOPS_PER_CYCLE)
                cost = _pipeline_cost(compute, dma, buf, steps,
                                      flops, f_b, itfc)
                if buf > 1 and not cost.pipelined:
                    continue
                total = cost.total * m_sweeps
                if best is None or total < best.est_total_cycles:
                    best = KernelSchedule(
                        name="group_aggregate",
                        block_shapes={"centers": (bm, k_nb),
                                      "f": (bn, channels)},
                        buffering=buf,
                        est_step_cycles=cost.step,
                        est_total_cycles=total,
                        vmem_bytes=vmem,
                        decisions={
                            "bound": "compute"
                                     if cost.step <= compute * (1 + 1e-9)
                                     else "memory",
                            "steps": str(steps * m_sweeps),
                            "pipeline": _pipe_note(cost, buf),
                        },
                        pipelined=cost.pipelined,
                        est_serial_cycles=cost.serial_total * m_sweeps,
                        pipeline_gain=cost.gain)
    assert best is not None, "no feasible group-aggregate tiling"
    return best
