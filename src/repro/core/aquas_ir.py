"""Aquas-IR: the three-level intermediate representation of paper §4.2.

Levels (Table 1):

  Functional     — access-mechanism-agnostic ops: ``transfer``, ``fetch``,
                   ``read_smem``.  μ-arch feature exposed: transfer size m.
  Architectural  — ops bound to one physical ``!memitfc<>`` symbol: ``copy``
                   (bulk) / ``load`` (scalar); legality now subject to the
                   chosen interface's constraints (W, M); latency estimable
                   via (I, L, E); cache penalties via C.
  Temporal       — asynchronous ``copy_issue``/``copy_wait`` pairs whose order
                   is pinned by ``after`` attributes; exposes in-flight-aware
                   ordering and hierarchy/phase order.

In this JAX port the IR is a set of plain dataclasses.  ``Program`` holds a
flat op list plus scratchpad declarations and loop-context annotations used by
scratchpad-buffer elision.  ``synthesis.py`` lowers Functional → Architectural
→ Temporal; ``kernel_synth.py`` interprets the temporal program as a Pallas
DMA pipeline schedule.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from repro.core.interface_model import MemInterface


class CacheHint(enum.Enum):
    """§4.1 cache_hint labels: cold data goes straight to DRAM-level paths,
    warm data favours higher (closer) hierarchy levels."""

    COLD = "cold"
    WARM = "warm"
    NONE = "none"


class Space(enum.Enum):
    GLOBAL = "global"       # main memory (TPU: HBB/HBM)
    SCRATCHPAD = "smem"     # explicit local buffer (TPU: VMEM staging)
    REG = "reg"             # register/vreg destination


@dataclasses.dataclass
class ScratchpadDecl:
    name: str
    size_bytes: int
    cache_hint: CacheHint = CacheHint.NONE
    # Elision-analysis context (§4.3): elision is disabled for scratchpads
    # accessed within unrolled regions, outside pipelined loops, or used
    # purely as local temporaries.
    accessed_in_unrolled_region: bool = False
    inside_pipelined_loop: bool = True
    purely_local_temp: bool = False
    # Affine reuse factor: how many times each element is re-read per staging.
    # reuse > 1 means elision would multiply global traffic by `reuse`.
    reuse_factor: int = 1
    # Per-element access can be hidden behind this many cycles of compute
    # (paper: bias[i] latency "effectively hidden by the accumulation").
    compute_cycles_per_elem: float = 0.0
    elem_bytes: int = 4


# ---------------------------------------------------------------------------
# Functional level
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FuncOp:
    """Functional-level memory op: mechanism-agnostic."""

    kind: str                  # "transfer" | "fetch" | "read_smem" | "write_smem"
    name: str                  # ssa-ish identifier of the moved value
    size_bytes: int
    src_space: Space
    dst_space: Space
    direction: str             # "load" | "store" (w.r.t. the ISAX datapath)
    cache_hint: CacheHint = CacheHint.NONE
    scratchpad: Optional[str] = None   # set for read_smem/write_smem
    base_align: int = 4096     # assumed base address alignment


@dataclasses.dataclass
class FunctionalProgram:
    name: str
    ops: list[FuncOp]
    scratchpads: dict[str, ScratchpadDecl] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Architectural level
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ArchOp:
    """Architectural-level op bound to exactly one interface (``copy # bulk``
    or ``load # scalar``), already canonicalized into one legal transfer."""

    kind: str                  # "copy" | "load" | "store"
    name: str                  # originating functional op name
    size_bytes: int            # legal for `itfc`
    itfc: MemInterface
    direction: str             # "load" | "store"
    seq_index: int             # position within the originating op's split
    cache_hint: CacheHint = CacheHint.NONE

    def __post_init__(self) -> None:
        if not self.itfc.is_legal_transaction(self.size_bytes):
            raise ValueError(
                f"{self.kind} {self.name}[{self.seq_index}]: {self.size_bytes}B "
                f"is not a legal transaction on {self.itfc.name} "
                f"(W={self.itfc.W}, M={self.itfc.M})")


@dataclasses.dataclass
class ArchitecturalProgram:
    name: str
    ops: list[ArchOp]
    scratchpads: dict[str, ScratchpadDecl] = dataclasses.field(default_factory=dict)
    # synthesis log: which functional decisions were taken
    decisions: dict[str, str] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Temporal level
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TemporalOp:
    """Asynchronous issue/wait pair; ordering guaranteed by ``after``."""

    kind: str                  # "copy_issue" | "copy_wait" | "load_issue" | ...
    op_id: int
    name: str
    size_bytes: int
    itfc: MemInterface
    direction: str
    after: Optional[int] = None    # op_id this one is ordered after
    issue_cycle: float = -1.0      # model-estimated
    complete_cycle: float = -1.0


@dataclasses.dataclass
class TemporalProgram:
    name: str
    ops: list[TemporalOp]
    total_cycles: float = 0.0
    scratchpads: dict[str, ScratchpadDecl] = dataclasses.field(default_factory=dict)
    decisions: dict[str, str] = dataclasses.field(default_factory=dict)

    def schedule_table(self) -> list[tuple[str, float, float]]:
        issues = [o for o in self.ops if o.kind.endswith("_issue")]
        return [(o.name, o.issue_cycle, o.complete_cycle) for o in issues]
