"""End-to-end retargetable compilation (paper §5, Figure 5).

``compile_program`` runs the full flow over a software term:

  (1) semantic alignment — programs and ISAXes are both written in the
      ``core/expr.py`` mini-IR (the "base dialect" level of §5.1), with loop
      indices alpha-normalized;
  (2) e-graph encoding (anchors/tuple, §5.2);
  (3) hybrid rewriting — internal algebraic saturation interleaved with
      ISAX-guided external loop transforms (§5.3);
  (4) skeleton-components matching, inserting ``isax:`` markers (§5.4);
  (5) extraction with an ISAX-prioritizing cost model → offloaded program.

``evaluate`` executes programs (numpy semantics) so tests can assert that the
offloaded program is bit-compatible (allclose) with the original — with ISAX
intrinsics bound to fused kernel implementations from ``kernels/``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core import expr
from repro.core.egraph import EGraph
from repro.core.expr import Term, arr, const, for_, var
from repro.core.matching import ISAX, decompose, match_isax
from repro.core.rewrites import (
    external_rewrite_pass,
    saturate_internal,
    structure_distance,
)


# ---------------------------------------------------------------------------
# Compilation statistics (paper Table 3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompileStats:
    case: str
    internal_rewrites: int = 0
    external_rewrites: int = 0
    initial_enodes: int = 0
    saturated_enodes: int = 0
    matched_isaxes: list[str] = dataclasses.field(default_factory=list)

    def row(self) -> str:
        return (f"{self.case},{self.internal_rewrites},"
                f"{self.external_rewrites},{self.initial_enodes},"
                f"{self.saturated_enodes},{'+'.join(self.matched_isaxes) or '-'}")


@dataclasses.dataclass
class OffloadResult:
    program: Term
    stats: CompileStats
    egraph: EGraph


def offload_cost(op: str, child_costs: list[float]) -> float:
    """Extraction cost model that prioritizes ISAX e-nodes (§5.4)."""
    if op.startswith("comp:"):
        return float("inf")
    if op.startswith("isax:"):
        return 1.0 + sum(child_costs)
    if op in ("matmul", "matvec", "outer", "gather", "ballsel"):
        return 200.0 + sum(child_costs)
    if op in ("exp", "sqrt", "rsqrt", "recip", "rowmax", "rowsum", "sum",
              "argmax", "colmax", "colmin", "rowmean"):
        return 20.0 + sum(child_costs)
    if op.startswith("for:"):
        return 50.0 + sum(child_costs)
    return 2.0 + sum(child_costs)


def compile_program(
    program: Term,
    isaxes: list[ISAX],
    case: str = "case",
    max_hybrid_rounds: int = 3,
    node_limit: int = 60_000,
) -> OffloadResult:
    program = expr.normalize_indices(program)
    eg = EGraph(node_limit=node_limit)
    root = eg.add_term(program)
    stats = CompileStats(case=case, initial_enodes=eg.n_nodes())

    skels = {ix.name: decompose(ix) for ix in isaxes}

    # Hybrid rewriting until saturation (or rounds exhausted): internal
    # algebraic saturation, then ISAX-guided external loop restructuring.
    for _ in range(max_hybrid_rounds):
        stats.internal_rewrites += saturate_internal(eg)
        ext_applied = 0
        for ix in isaxes:
            st = external_rewrite_pass(eg, root, skels[ix.name].loop_struct)
            ext_applied += st.applied
        stats.external_rewrites += ext_applied
        if ext_applied == 0:
            break
    stats.internal_rewrites += saturate_internal(eg, max_iters=2)

    # Skeleton-components matching per ISAX.
    for ix in isaxes:
        for m in match_isax(eg, ix, skels[ix.name]):
            stats.matched_isaxes.append(m.isax)

    stats.saturated_enodes = eg.n_nodes()
    out = eg.extract(eg.find(root), offload_cost)
    return OffloadResult(out, stats, eg)


# ---------------------------------------------------------------------------
# Evaluator (numpy semantics) — correctness oracle for offloaded programs
# ---------------------------------------------------------------------------

IntrinsicFn = Callable[..., None]  # mutates output array arguments in place

_INTRINSICS: dict[str, IntrinsicFn] = {}


def register_intrinsic(name: str, fn: IntrinsicFn) -> None:
    _INTRINSICS[name] = fn


def evaluate(t: Term, env: dict, intrinsics: dict[str, IntrinsicFn] | None = None):
    """Execute a program term.  ``env`` maps array/var names to numpy arrays /
    scalars; stores mutate arrays in place.  Returns the last value."""
    table = dict(_INTRINSICS)
    if intrinsics:
        table.update(intrinsics)
    return _eval(t, env, table)


def _eval(t: Term, env: dict, intr) -> object:
    o = expr.op(t)
    kind = expr.leaf_kind(o)
    if kind == "const":
        return expr.leaf_value(o)
    if kind in ("var", "arr"):
        return env[o.split(":", 1)[1]]
    ch = expr.children(t)

    if o == "tuple":
        out = None
        for c in ch:
            out = _eval(c, env, intr)
        return out
    if expr.is_for(t):
        idx = expr.for_index(t)
        start = int(_eval(ch[0], env, intr))
        end = int(_eval(ch[1], env, intr))
        step = int(_eval(ch[2], env, intr))
        saved = env.get(idx, _MISSING)
        for v in range(start, end, step):
            env[idx] = v
            _eval(ch[3], env, intr)
        if saved is _MISSING:
            env.pop(idx, None)
        else:
            env[idx] = saved
        return None
    if o == "store":
        a = _eval(ch[0], env, intr)
        idxs = tuple(int(_eval(c, env, intr)) for c in ch[1:-1])
        val = _eval(ch[-1], env, intr)
        a[idxs] = val
        return None
    if o == "load":
        a = _eval(ch[0], env, intr)
        idxs = tuple(int(_eval(c, env, intr)) for c in ch[1:])
        return a[idxs]
    if o.startswith("isax:"):
        name = o.split(":", 1)[1]
        args = [_eval(c, env, intr) for c in ch]
        intr[name](*args)
        return None

    args = [_eval(c, env, intr) for c in ch]
    return _apply(o, args)


_MISSING = object()


def _apply(o: str, a: list):
    import numpy as np
    if o == "+":
        return a[0] + a[1]
    if o == "-":
        return a[0] - a[1]
    if o == "*":
        return a[0] * a[1]
    if o == "/":
        return a[0] / a[1]
    if o == "<<":
        return a[0] << a[1]
    if o == ">>":
        return a[0] >> a[1]
    if o == "neg":
        return -a[0]
    if o == "exp":
        return np.exp(a[0])
    if o == "sqrt":
        return np.sqrt(a[0])
    if o == "rsqrt":
        return 1.0 / np.sqrt(a[0])
    if o == "recip":
        return 1.0 / a[0]
    if o in ("relu", "max0"):
        return np.maximum(a[0], 0)
    if o == "max":
        return np.maximum(a[0], a[1])
    if o == "min":
        return np.minimum(a[0], a[1])
    if o == "rowmax":
        return np.max(a[0], axis=-1)
    if o == "argmax":
        return int(np.argmax(a[0]))
    if o == "colmax":
        return np.max(a[0], axis=0)
    if o == "colmin":
        return np.min(a[0], axis=0)
    if o == "gather":
        return a[0][np.asarray(a[1], np.int64)]
    if o == "ballsel":
        # first-K in-radius indices (ascending), padded with the first hit;
        # no point in radius → the nearest point (see pointcloud/ref.py)
        d, r2, k = np.asarray(a[0]), float(a[1]), int(a[2])
        hits = np.nonzero(d <= r2)[0][:k]
        if hits.size == 0:
            return np.full((k,), int(np.argmin(d)), np.int64)
        return np.concatenate(
            [hits, np.full((k - hits.size,), hits[0], np.int64)])
    if o == "rowsum":
        return np.sum(a[0], axis=-1)
    if o == "rowmean":
        return np.mean(a[0], axis=-1)
    if o == "sum":
        return np.sum(a[0])
    if o == "matmul":
        return a[0] @ a[1]
    if o == "matvec":
        return a[0] @ a[1]
    if o == "outer":
        return np.outer(a[0], a[1])
    if o == "transpose":
        return np.transpose(a[0])
    if o == "dot":
        return np.dot(a[0], a[1])
    if o == "select":
        return np.where(a[0], a[1], a[2])
    raise NotImplementedError(f"evaluator op {o}")


# ---------------------------------------------------------------------------
# ISAX library: the specialized datapaths this "ASIP" ships (§6 analogues)
# ---------------------------------------------------------------------------

def isax_flash_attention() -> ISAX:
    """Row-blocked attention: for each query row i, S[i] = softmax-numerator,
    O[i] = normalized PV product.  Two components under two store anchors in
    a single-loop skeleton (the paper's Figure 5 shape, adapted)."""
    i = var("i")
    q_row = ("load", arr("Q"), i)
    s_row = ("/",
             ("exp", ("-", ("*", var("scale"), ("matvec", arr("K"), q_row)),
                      ("rowmax", ("*", var("scale"),
                                  ("matvec", arr("K"), q_row))))),
             ("rowsum", ("exp", ("-", ("*", var("scale"),
                                       ("matvec", arr("K"), q_row)),
                                 ("rowmax", ("*", var("scale"),
                                             ("matvec", arr("K"), q_row)))))))
    body_s = ("store", arr("P"), i, s_row)
    body_o = ("store", arr("O"), i,
              ("matvec", ("transpose", arr("V")), ("load", arr("P"), i)))
    term = for_("i", const(0), var("n_q"), const(1), body_s, body_o)
    return ISAX(
        name="flash_attention",
        params=("Q", "K", "V", "scale", "n_q", "P", "O"),
        term=term,
        kernel="flash_attention",
        outputs=("P", "O"),
    )


def isax_int8_matvec() -> ISAX:
    """Quantized GEMV: C[i] = s_w * (Wq @ x[i]) — the LLM-inference ISAX
    (paper §6.5 uses 8-bit quantized Llama attention/FFN)."""
    i = var("i")
    term = for_("i", const(0), var("n"), const(1),
                ("store", arr("C"), i,
                 ("*", var("s_w"),
                  ("matvec", arr("Wq"), ("load", arr("X"), i)))))
    return ISAX(
        name="int8_matvec",
        params=("Wq", "X", "s_w", "n", "C"),
        term=term,
        kernel="int8_matmul",
        outputs=("C",),
    )


def isax_ssd_step() -> ISAX:
    """SSD (state-space duality) recurrence: H ← a_t·H + B_t⊗x_t;
    y_t = H^T·C_t.  Loop-carried dependence through H (tests the §5.4
    loop-carried check)."""
    t = var("t")
    upd = ("+",
           ("*", ("load", arr("A"), t), ("load", arr("H"), const(0))),
           ("outer", ("load", arr("B"), t), ("load", arr("X"), t)))
    out = ("matvec", ("transpose", ("load", arr("H"), const(0))),
           ("load", arr("C"), t))
    term = for_("t", const(0), var("T"), const(1),
                ("store", arr("H"), const(0), upd),
                ("store", arr("Y"), t, out))
    return ISAX(
        name="ssd_step",
        params=("A", "B", "C", "X", "T", "H", "Y"),
        term=term,
        kernel="ssd_scan",
        outputs=("H", "Y"),
    )


def isax_rmsnorm() -> ISAX:
    """Fused RMSNorm row op: O[i] = x * rsqrt(mean(x²) + eps) * g."""
    i = var("i")
    x = ("load", arr("Xn"), i)
    term = for_("i", const(0), var("n"), const(1),
                ("store", arr("On"), i,
                 ("*", ("*", x, ("rsqrt",
                                 ("+", ("rowmean", ("*", x, x)),
                                  var("eps")))),
                  arr("G"))))
    return ISAX(
        name="rmsnorm",
        params=("Xn", "G", "eps", "n", "On"),
        term=term,
        kernel="rmsnorm",
        outputs=("On",),
    )


def isax_swiglu() -> ISAX:
    """Fused SwiGLU MLP row op: O[i] = ((Wg·x)·σ(Wg·x) ⊙ (Wu·x))ᵀ·Wo —
    written with silu expanded to its x·sigmoid(x) = x/(1+exp(−x)) form so
    software variants using either spelling match."""
    i = var("i")
    x = ("load", arr("Xs"), i)
    g = ("matvec", arr("Wg"), x)
    u = ("matvec", arr("Wu"), x)
    silu_g = ("/", g, ("+", ("const:1",), ("exp", ("neg", g))))
    term = for_("i", const(0), var("n"), const(1),
                ("store", arr("Os"), i,
                 ("matvec", ("transpose", arr("Wo")),
                  ("*", silu_g, u))))
    return ISAX(
        name="swiglu",
        params=("Wg", "Wu", "Wo", "Xs", "n", "Os"),
        term=term,
        kernel="swiglu",
        outputs=("Os",),
    )


def _sqdist(a: Term, b: Term) -> Term:
    """Compact row-wise squared distance ‖a − b‖² (the ISAX-side spelling;
    software variants spell it expanded — see ``rewrites.sqdist-expand``)."""
    return ("rowsum", ("*", ("-", a, b), ("-", a, b)))


def isax_fps() -> ISAX:
    """Farthest-point sampling: S[s] = argmax of the running min-distance,
    D ← min(D, ‖X − X[S[s]]‖²).  Loop-carried dependences through *both*
    outputs (S feeds the distance update of the same iteration, D feeds the
    argmax of the next) — the point-cloud stress test for the §5.4
    loop-carried checks."""
    s = var("s")
    term = for_("s", const(0), var("n_s"), const(1),
                ("store", arr("Sp"), s,
                 ("argmax", ("load", arr("Dp"), const(0)))),
                ("store", arr("Dp"), const(0),
                 ("min", ("load", arr("Dp"), const(0)),
                  _sqdist(arr("Xp"),
                          ("load", arr("Xp"), ("load", arr("Sp"), s))))))
    return ISAX(
        name="fps",
        params=("Xp", "n_s", "Dp", "Sp"),
        term=term,
        kernel="fps",
        outputs=("Dp", "Sp"),
    )


def isax_ball_query() -> ISAX:
    """Ball query / kNN grouping: G[j] = first-kk indices of X within
    radius² of center j (padded; nearest point when the ball is empty).
    The irregular-gather front half of PointNet++ set abstraction."""
    j = var("j")
    term = for_("j", const(0), var("n_c"), const(1),
                ("store", arr("Gq"), j,
                 ("ballsel",
                  _sqdist(arr("Xp"), ("load", arr("Cn"), j)),
                  var("r2"), var("kk"))))
    return ISAX(
        name="ball_query",
        params=("Xp", "Cn", "r2", "kk", "n_c", "Gq"),
        term=term,
        kernel="ball_query",
        outputs=("Gq",),
    )


def isax_group_agg() -> ISAX:
    """Grouped feature aggregation: A[j] = max-pool over the rows of F
    gathered by neighbor list G[j] (the fused PointNet++ set-abstraction
    datapath: gather + reduce in one pass over the feature array)."""
    j = var("j")
    term = for_("j", const(0), var("n_c"), const(1),
                ("store", arr("Ag"), j,
                 ("colmax", ("gather", arr("Fg"),
                             ("load", arr("Gq"), j)))))
    return ISAX(
        name="group_agg",
        params=("Fg", "Gq", "n_c", "Ag"),
        term=term,
        kernel="group_aggregate",
        outputs=("Ag",),
    )


def isax_library() -> list[ISAX]:
    return [isax_flash_attention(), isax_int8_matvec(), isax_ssd_step(),
            isax_rmsnorm(), isax_swiglu(), isax_fps(), isax_ball_query(),
            isax_group_agg()]


# ---------------------------------------------------------------------------
# Reference numpy intrinsics (kernels/ops.py registers the fused/Pallas ones)
# ---------------------------------------------------------------------------

def _np_flash_attention(Q, K, V, scale, n_q, P, O):
    S = (Q @ K.T) * scale
    Pm = np.exp(S - S.max(axis=-1, keepdims=True))
    P[:] = Pm / Pm.sum(axis=-1, keepdims=True)
    O[:] = P @ V


def _np_int8_matvec(Wq, X, s_w, n, C):
    C[:] = (X @ Wq.astype(np.float64).T) * s_w


def _np_ssd_scan(A, B, C, X, T, H, Y):
    h = H[0]
    for t in range(int(T)):
        h = A[t] * h + np.outer(B[t], X[t])
        Y[t] = h.T @ C[t]
    H[0] = h


def _np_rmsnorm(Xn, G, eps, n, On):
    ms = np.mean(Xn * Xn, axis=-1, keepdims=True)
    On[:] = Xn / np.sqrt(ms + eps) * G


def _np_swiglu(Wg, Wu, Wo, Xs, n, Os):
    g = Xs @ Wg.T
    u = Xs @ Wu.T
    Os[:] = (g / (1.0 + np.exp(-g)) * u) @ Wo


def _np_fps(Xp, n_s, Dp, Sp):
    d = Dp[0]
    for s in range(int(n_s)):
        Sp[s] = int(np.argmax(d))
        diff = Xp - Xp[Sp[s]]
        d = np.minimum(d, (diff * diff).sum(-1))
    Dp[0] = d


def _np_ball_query(Xp, Cn, r2, kk, n_c, Gq):
    k = int(kk)
    for j in range(int(n_c)):
        diff = Xp - Cn[j]
        d = (diff * diff).sum(-1)
        hits = np.nonzero(d <= float(r2))[0][:k]
        if hits.size == 0:
            Gq[j] = int(np.argmin(d))
        else:
            Gq[j, :hits.size] = hits
            Gq[j, hits.size:] = hits[0]


def _np_group_agg(Fg, Gq, n_c, Ag):
    for j in range(int(n_c)):
        Ag[j] = Fg[np.asarray(Gq[j], np.int64)].max(axis=0)


register_intrinsic("flash_attention", _np_flash_attention)
register_intrinsic("int8_matvec", _np_int8_matvec)
register_intrinsic("ssd_step", _np_ssd_scan)
register_intrinsic("rmsnorm", _np_rmsnorm)
register_intrinsic("swiglu", _np_swiglu)
register_intrinsic("fps", _np_fps)
register_intrinsic("ball_query", _np_ball_query)
register_intrinsic("group_agg", _np_group_agg)
