"""End-to-end retargetable compilation (paper §5, Figure 5).

``compile_program`` runs the full flow over a software term:

  (1) semantic alignment — programs and ISAXes are both written in the
      ``core/expr.py`` mini-IR (the "base dialect" level of §5.1), with loop
      indices alpha-normalized;
  (2) e-graph encoding (anchors/tuple, §5.2);
  (3) hybrid rewriting — internal algebraic saturation interleaved with
      ISAX-guided external loop transforms (§5.3);
  (4) skeleton-components matching, inserting ``isax:`` markers (§5.4);
  (5) extraction with an ISAX-prioritizing cost model → offloaded program.

``evaluate`` executes programs (numpy semantics) so tests can assert that the
offloaded program is bit-compatible (allclose) with the original — with ISAX
intrinsics derived from the ``repro.targets`` registry (every registered
``IsaxSpec.evaluator``), optionally overridden by fused kernel
implementations from ``kernels/`` via ``register_intrinsic``.

The ISAX *definitions* themselves live on the domain packages
(``repro/targets/llm.py``, ``repro/targets/pointcloud.py``);
``isax_library()`` survives here only as a deprecation shim.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable

from repro.core import expr
from repro.core.egraph import EGraph
from repro.core.expr import Term
from repro.core.matching import ISAX, decompose, match_isax
from repro.core.rewrites import (
    external_rewrite_pass,
    saturate_internal,
)


# ---------------------------------------------------------------------------
# Compilation statistics (paper Table 3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompileStats:
    case: str
    internal_rewrites: int = 0
    external_rewrites: int = 0
    initial_enodes: int = 0
    saturated_enodes: int = 0
    matched_isaxes: list[str] = dataclasses.field(default_factory=list)

    def row(self) -> str:
        return (f"{self.case},{self.internal_rewrites},"
                f"{self.external_rewrites},{self.initial_enodes},"
                f"{self.saturated_enodes},{'+'.join(self.matched_isaxes) or '-'}")


@dataclasses.dataclass
class OffloadResult:
    program: Term
    stats: CompileStats
    egraph: EGraph


def offload_cost(op: str, child_costs: list[float]) -> float:
    """Extraction cost model that prioritizes ISAX e-nodes (§5.4)."""
    if op.startswith("comp:"):
        return float("inf")
    if op.startswith("isax:"):
        return 1.0 + sum(child_costs)
    if op in ("matmul", "matvec", "outer", "gather", "ballsel"):
        return 200.0 + sum(child_costs)
    if op in ("exp", "sqrt", "rsqrt", "recip", "rowmax", "rowsum", "sum",
              "argmax", "colmax", "colmin", "rowmean"):
        return 20.0 + sum(child_costs)
    if op.startswith("for:"):
        return 50.0 + sum(child_costs)
    return 2.0 + sum(child_costs)


def compile_program(
    program: Term,
    isaxes: list[ISAX],
    case: str = "case",
    max_hybrid_rounds: int = 3,
    node_limit: int = 60_000,
) -> OffloadResult:
    program = expr.normalize_indices(program)
    eg = EGraph(node_limit=node_limit)
    root = eg.add_term(program)
    stats = CompileStats(case=case, initial_enodes=eg.n_nodes())

    skels = {ix.name: decompose(ix) for ix in isaxes}

    # Hybrid rewriting until saturation (or rounds exhausted): internal
    # algebraic saturation, then ISAX-guided external loop restructuring.
    for _ in range(max_hybrid_rounds):
        stats.internal_rewrites += saturate_internal(eg)
        ext_applied = 0
        for ix in isaxes:
            st = external_rewrite_pass(eg, root, skels[ix.name].loop_struct)
            ext_applied += st.applied
        stats.external_rewrites += ext_applied
        if ext_applied == 0:
            break
    stats.internal_rewrites += saturate_internal(eg, max_iters=2)

    # Skeleton-components matching per ISAX.
    for ix in isaxes:
        for m in match_isax(eg, ix, skels[ix.name]):
            stats.matched_isaxes.append(m.isax)

    stats.saturated_enodes = eg.n_nodes()
    out = eg.extract(eg.find(root), offload_cost)
    return OffloadResult(out, stats, eg)


# ---------------------------------------------------------------------------
# Evaluator (numpy semantics) — correctness oracle for offloaded programs
# ---------------------------------------------------------------------------

IntrinsicFn = Callable[..., None]  # mutates output array arguments in place

_INTRINSICS: dict[str, IntrinsicFn] = {}


def register_intrinsic(name: str, fn: IntrinsicFn) -> None:
    """Override the intrinsic bound to ``isax:<name>`` e-nodes (used by
    ``kernels/ops.py`` / ``pointcloud/ops.py`` to swap the registry's numpy
    semantics for the fused/Pallas-backed datapaths)."""
    _INTRINSICS[name] = fn


def _registry_intrinsics() -> dict[str, IntrinsicFn]:
    """Evaluator semantics derived from the ``repro.targets`` registry
    (imported lazily: targets depends on core, not the other way around)."""
    from repro import targets
    return targets.evaluators()


def evaluate(t: Term, env: dict, intrinsics: dict[str, IntrinsicFn] | None = None):
    """Execute a program term.  ``env`` maps array/var names to numpy arrays /
    scalars; stores mutate arrays in place.  Returns the last value.

    Intrinsic resolution order: registry evaluator semantics (every
    registered ``IsaxSpec.evaluator``), then ``register_intrinsic``
    overrides, then the per-call ``intrinsics`` table."""
    table = _registry_intrinsics()
    table.update(_INTRINSICS)
    if intrinsics:
        table.update(intrinsics)
    return _eval(t, env, table)


def _eval(t: Term, env: dict, intr) -> object:
    o = expr.op(t)
    kind = expr.leaf_kind(o)
    if kind == "const":
        return expr.leaf_value(o)
    if kind in ("var", "arr"):
        return env[o.split(":", 1)[1]]
    ch = expr.children(t)

    if o == "tuple":
        out = None
        for c in ch:
            out = _eval(c, env, intr)
        return out
    if expr.is_for(t):
        idx = expr.for_index(t)
        start = int(_eval(ch[0], env, intr))
        end = int(_eval(ch[1], env, intr))
        step = int(_eval(ch[2], env, intr))
        saved = env.get(idx, _MISSING)
        for v in range(start, end, step):
            env[idx] = v
            _eval(ch[3], env, intr)
        if saved is _MISSING:
            env.pop(idx, None)
        else:
            env[idx] = saved
        return None
    if o == "store":
        a = _eval(ch[0], env, intr)
        idxs = tuple(int(_eval(c, env, intr)) for c in ch[1:-1])
        val = _eval(ch[-1], env, intr)
        a[idxs] = val
        return None
    if o == "load":
        a = _eval(ch[0], env, intr)
        idxs = tuple(int(_eval(c, env, intr)) for c in ch[1:])
        return a[idxs]
    if o.startswith("isax:"):
        name = o.split(":", 1)[1]
        args = [_eval(c, env, intr) for c in ch]
        intr[name](*args)
        return None

    args = [_eval(c, env, intr) for c in ch]
    return _apply(o, args)


_MISSING = object()


def _apply(o: str, a: list):
    import numpy as np
    if o == "+":
        return a[0] + a[1]
    if o == "-":
        return a[0] - a[1]
    if o == "*":
        return a[0] * a[1]
    if o == "/":
        return a[0] / a[1]
    if o == "<<":
        return a[0] << a[1]
    if o == ">>":
        return a[0] >> a[1]
    if o == "neg":
        return -a[0]
    if o == "exp":
        return np.exp(a[0])
    if o == "sqrt":
        return np.sqrt(a[0])
    if o == "rsqrt":
        return 1.0 / np.sqrt(a[0])
    if o == "recip":
        return 1.0 / a[0]
    if o in ("relu", "max0"):
        return np.maximum(a[0], 0)
    if o == "max":
        return np.maximum(a[0], a[1])
    if o == "min":
        return np.minimum(a[0], a[1])
    if o == "rowmax":
        return np.max(a[0], axis=-1)
    if o == "argmax":
        return int(np.argmax(a[0]))
    if o == "colmax":
        return np.max(a[0], axis=0)
    if o == "colmin":
        return np.min(a[0], axis=0)
    if o == "gather":
        return a[0][np.asarray(a[1], np.int64)]
    if o == "ballsel":
        # first-K in-radius indices (ascending), padded with the first hit;
        # no point in radius → the nearest point (see pointcloud/ref.py)
        d, r2, k = np.asarray(a[0]), float(a[1]), int(a[2])
        hits = np.nonzero(d <= r2)[0][:k]
        if hits.size == 0:
            return np.full((k,), int(np.argmin(d)), np.int64)
        return np.concatenate(
            [hits, np.full((k - hits.size,), hits[0], np.int64)])
    if o == "rowsum":
        return np.sum(a[0], axis=-1)
    if o == "rowmean":
        return np.mean(a[0], axis=-1)
    if o == "sum":
        return np.sum(a[0])
    if o == "matmul":
        return a[0] @ a[1]
    if o == "matvec":
        return a[0] @ a[1]
    if o == "outer":
        return np.outer(a[0], a[1])
    if o == "transpose":
        return np.transpose(a[0])
    if o == "dot":
        return np.dot(a[0], a[1])
    if o == "select":
        return np.where(a[0], a[1], a[2])
    raise NotImplementedError(f"evaluator op {o}")


# ---------------------------------------------------------------------------
# ISAX library — MOVED: definitions now live on the ``repro.targets`` domain
# packages (``targets/llm.py``, ``targets/pointcloud.py``); this module
# keeps deprecation/compat shims only.
# ---------------------------------------------------------------------------

def isax_library() -> list[ISAX]:
    """Deprecated: the ISAX library is derived from the ``repro.targets``
    registry.  Use ``repro.targets.isax_library()`` (or iterate
    ``default_registry().specs()``) instead; this shim survives for one
    release."""
    warnings.warn(
        "repro.core.offload.isax_library() is deprecated; the library is "
        "derived from the repro.targets registry — call "
        "repro.targets.isax_library() instead", DeprecationWarning,
        stacklevel=2)
    from repro import targets
    return targets.isax_library()


def __getattr__(name: str):
    """Back-compat for the moved ISAX factories and numpy evaluators.

    ``isax_<name>()`` / ``_np_<name>`` now live on the domain packages
    (``repro.targets.llm``, ``repro.targets.pointcloud``); old imports keep
    resolving through this hook for one release.
    """
    if name.startswith(("isax_", "_np_")):
        from repro.targets import llm, pointcloud
        for mod in (llm, pointcloud):
            if hasattr(mod, name):
                warnings.warn(
                    f"repro.core.offload.{name} moved to {mod.__name__}; "
                    "import it from there (this forwarding shim lasts one "
                    "release)", DeprecationWarning, stacklevel=2)
                return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


