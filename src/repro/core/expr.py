"""Mini functional IR for model-graph fragments (the "software side").

Aquas canonicalizes software (via Polygeist → MLIR) and ISAX descriptions
(Aquas-IR functional level) to a common abstraction in base MLIR dialects
(§5.1).  We do not embed MLIR; instead both sides are written in this small
term IR, which plays the role of the base dialects.

A term is an immutable nested tuple ``(op, *children)``:

  dataflow ops : '+', '-', '*', '/', '<<', '>>', 'min', 'max', 'exp', 'neg',
                 'matmul', 'dot', 'select', 'sqrt', 'rsqrt', 'relu', 'sum',
                 'rowmax', 'rowsum', 'recip', 'load' (array, *index)
  leaves       : ('var:<name>',), ('const:<int-or-float>',), ('arr:<name>',)
  anchors      : ('store', arr, *index, value)        — side-effecting
                 ('for:<idx>', start, end, step, body) — structured control
                 ('yield', *values)                   — terminator
  block        : ('tuple', *anchors)                  — §5.2 block encoding

The loop induction variable is carried in the op string (``for:i``) and
referenced in the body as ``('var:i',)``.  ``normalize_indices`` renames all
induction variables to canonical depth-based names (``i0``, ``i1``, …) so
alpha-equivalent loops share e-nodes and skeleton matching is name-stable.

Programs written here are *descriptions* of layer computations used by the
retargetable compiler; execution for validation happens in
``core/offload.py``'s evaluator (numpy/jnp semantics).
"""

from __future__ import annotations

from typing import Iterator

Term = tuple  # (op: str, *children: Term)

ANCHOR_OPS = {"store", "yield", "while", "isax_call"}  # plus any 'for:*'


def is_anchor_op(o: str) -> bool:
    return o in ANCHOR_OPS or o.startswith("for:") or o.startswith("isax:")
COMMUTATIVE = {"+", "*", "min", "max", "and", "or"}
SIDE_EFFECT = {"store", "isax_call"}

# Ops whose cost is "heavy" (matrix unit) vs "light" (vector unit):
HEAVY_OPS = {"matmul", "dot"}


def is_leaf(t: Term) -> bool:
    return len(t) == 1


def op(t: Term) -> str:
    return t[0]


def children(t: Term) -> tuple:
    return tuple(t[1:])


def var(name: str) -> Term:
    return (f"var:{name}",)


def const(v) -> Term:
    return (f"const:{v}",)


def arr(name: str) -> Term:
    return (f"arr:{name}",)


def leaf_kind(o: str) -> str | None:
    for k in ("var", "const", "arr"):
        if o.startswith(k + ":"):
            return k
    return None


def leaf_value(o: str):
    kind = leaf_kind(o)
    if kind is None:
        return None
    payload = o.split(":", 1)[1]
    if kind == "const":
        try:
            return int(payload)
        except ValueError:
            return float(payload)
    return payload


def const_value(t: Term):
    if is_leaf(t) and op(t).startswith("const:"):
        return leaf_value(op(t))
    return None


def walk(t: Term) -> Iterator[Term]:
    yield t
    for c in children(t):
        yield from walk(c)


def count_nodes(t: Term) -> int:
    return sum(1 for _ in walk(t))


def rename_var(t: Term, old: str, new: str) -> Term:
    if is_leaf(t):
        return var(new) if op(t) == f"var:{old}" else t
    return (op(t),) + tuple(rename_var(c, old, new) for c in children(t))


def substitute_var(t: Term, name: str, replacement: Term) -> Term:
    if is_leaf(t):
        return replacement if op(t) == f"var:{name}" else t
    return (op(t),) + tuple(substitute_var(c, name, replacement)
                            for c in children(t))


def is_for(t: Term) -> bool:
    return op(t).startswith("for:")


def for_index(t: Term) -> str:
    assert is_for(t)
    return op(t).split(":", 1)[1]


def for_(idx: str, start: Term, end: Term, step: Term, *anchors: Term) -> Term:
    body = anchors[0] if len(anchors) == 1 and op(anchors[0]) == "tuple" \
        else ("tuple",) + tuple(anchors)
    return (f"for:{idx}", start, end, step, body)


def loop_structure(t: Term) -> tuple | None:
    """Structural summary of a loop nest: (trip_count_or_None, step, [nested])
    used by ISAX-guided external rewriting (§5.3: "The decision here only
    depends on the loop structure, not the specific operations within")."""
    if not is_for(t):
        return None
    start, end, step, body = children(t)
    s, e, st = const_value(start), const_value(end), const_value(step)
    trip = None
    if s is not None and e is not None and st not in (None, 0):
        trip = max(0, -(-(e - s) // st))
    nested = []
    if op(body) == "tuple":
        for anchor in children(body):
            if is_for(anchor):
                nested.append(loop_structure(anchor))
    return (trip, st, tuple(nested))


def normalize_indices(t: Term, depth: int = 0, mapping=None) -> Term:
    """Alpha-rename induction variables to i0, i1, … by nesting depth."""
    mapping = mapping or {}
    o = op(t)
    if is_leaf(t):
        if o.startswith("var:"):
            nm = o.split(":", 1)[1]
            if nm in mapping:
                return var(mapping[nm])
        return t
    if is_for(t):
        idx = for_index(t)
        new_idx = f"i{depth}"
        m2 = dict(mapping)
        m2[idx] = new_idx
        start, end, step, body = children(t)
        return (f"for:{new_idx}",
                normalize_indices(start, depth, mapping),
                normalize_indices(end, depth, mapping),
                normalize_indices(step, depth, mapping),
                normalize_indices(body, depth + 1, m2))
    return (o,) + tuple(normalize_indices(c, depth, mapping)
                        for c in children(t))
