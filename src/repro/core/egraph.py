"""E-graph with anchor-aware program encoding (paper §2.3 and §5.2).

Standard equality-saturation machinery (union-find over e-classes, hashcons,
congruence-closure rebuild, e-matching, cost-based extraction — the egg [23]
recipe) plus the Aquas-specific program encoding:

  * each MLIR-block analogue becomes a ``tuple(...)`` e-node whose children
    are the block's *anchors* (terminators, side-effecting ops, structured
    control flow) in exact program order;
  * pure dataflow forms subtrees beneath the anchors that consume them.

Anchors are never rewritten by internal rules (rewrites.py guards on this),
which preserves ordering, dominance, and memory effects — the "critical
semantic relations" the paper calls out as overlooked by generic e-graph
pipelines.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Iterable, Iterator, Optional

from repro.core import expr
from repro.core.expr import Term

ENode = tuple  # (op: str, *child_eclass_ids: int)


class EGraph:
    def __init__(self, node_limit: int = 50_000):
        self._parent: list[int] = []
        self.hashcons: dict[ENode, int] = {}
        self.classes: dict[int, set[ENode]] = {}
        self.uses: dict[int, set[ENode]] = {}  # child class -> user enodes
        self.node_limit = node_limit
        self._dirty: list[int] = []

    # ---- union-find ---------------------------------------------------------

    def find(self, x: int) -> int:
        while self._parent[x] != x:
            self._parent[x] = self._parent[self._parent[x]]
            x = self._parent[x]
        return x

    def _new_class(self) -> int:
        cid = len(self._parent)
        self._parent.append(cid)
        self.classes[cid] = set()
        self.uses[cid] = set()
        return cid

    # ---- add / union / rebuild ---------------------------------------------

    def canonicalize(self, node: ENode) -> ENode:
        return (node[0],) + tuple(self.find(c) for c in node[1:])

    def add_node(self, op: str, child_ids: Iterable[int]) -> int:
        node = (op,) + tuple(self.find(c) for c in child_ids)
        if node in self.hashcons:
            return self.find(self.hashcons[node])
        cid = self._new_class()
        self.hashcons[node] = cid
        self.classes[cid].add(node)
        for c in node[1:]:
            self.uses[c].add(node)
        return cid

    def add_term(self, t: Term) -> int:
        child_ids = [self.add_term(c) for c in expr.children(t)]
        return self.add_node(expr.op(t), child_ids)

    def union(self, a: int, b: int) -> int:
        a, b = self.find(a), self.find(b)
        if a == b:
            return a
        # keep the smaller id as representative (stable for tests)
        if a > b:
            a, b = b, a
        self._parent[b] = a
        self.classes.setdefault(a, set()).update(self.classes.pop(b, set()))
        self.uses.setdefault(a, set()).update(self.uses.pop(b, set()))
        self._dirty.append(a)
        return a

    def rebuild(self) -> None:
        """Congruence closure via full re-canonicalization to fixpoint.

        Graphs in this domain are small (the paper's Table 3 tops out at
        ~2.8k e-nodes), so the O(n)-per-pass full rebuild is simpler and
        safer than incremental worklists.
        """
        while True:
            self._dirty.clear()
            new_hashcons: dict[ENode, int] = {}
            merged = False
            for node, cid in self.hashcons.items():
                canon = self.canonicalize(node)
                owner = self.find(cid)
                if canon in new_hashcons:
                    other = self.find(new_hashcons[canon])
                    if other != owner:
                        self.union(owner, other)
                        merged = True
                    new_hashcons[canon] = self.find(owner)
                else:
                    new_hashcons[canon] = owner
            self.hashcons = new_hashcons
            # rebuild classes/uses tables from the canonical hashcons
            classes: dict[int, set[ENode]] = {}
            uses: dict[int, set[ENode]] = {}
            for node, cid in self.hashcons.items():
                cid = self.find(cid)
                classes.setdefault(cid, set()).add(node)
                uses.setdefault(cid, set())
                for ch in node[1:]:
                    uses.setdefault(self.find(ch), set()).add(node)
            self.classes = classes
            self.uses = uses
            if not merged:
                break

    # ---- introspection -------------------------------------------------------

    def n_nodes(self) -> int:
        return len(self.hashcons)

    def n_classes(self) -> int:
        return len({self.find(i) for i in range(len(self._parent))})

    def nodes_of(self, cid: int) -> set[ENode]:
        return self.classes.get(self.find(cid), set())

    def class_has_op(self, cid: int, op: str) -> bool:
        return any(n[0] == op for n in self.nodes_of(cid))

    def iter_classes(self) -> Iterator[tuple[int, set[ENode]]]:
        for cid in list(self.classes.keys()):
            if self.find(cid) == cid:
                yield cid, self.classes[cid]

    # ---- e-matching ----------------------------------------------------------
    #
    # Patterns are Terms whose leaves may be pattern variables ('?x',).
    # A match yields a substitution {?x: eclass_id} plus the matched root id.

    def ematch(self, pattern: Term) -> list[tuple[dict[str, int], int]]:
        out = []
        for cid, _ in self.iter_classes():
            for sub in self._match_class(pattern, cid, {}):
                out.append((sub, cid))
        return out

    def _match_class(self, pattern: Term, cid: int,
                     sub: dict[str, int]) -> Iterator[dict[str, int]]:
        cid = self.find(cid)
        p_op = expr.op(pattern)
        if p_op.startswith("?"):
            bound = sub.get(p_op)
            if bound is None:
                s2 = dict(sub)
                s2[p_op] = cid
                yield s2
            elif self.find(bound) == cid:
                yield sub
            return
        for node in list(self.nodes_of(cid)):
            if node[0] != p_op or len(node) - 1 != len(expr.children(pattern)):
                continue
            yield from self._match_children(
                expr.children(pattern), node[1:], sub)

    def _match_children(self, pats, cids, sub) -> Iterator[dict[str, int]]:
        if not pats:
            yield sub
            return
        for s in self._match_class(pats[0], cids[0], sub):
            yield from self._match_children(pats[1:], cids[1:], s)

    def instantiate(self, pattern: Term, sub: dict[str, int]) -> int:
        p_op = expr.op(pattern)
        if p_op.startswith("?"):
            return self.find(sub[p_op])
        child_ids = [self.instantiate(c, sub) for c in expr.children(pattern)]
        return self.add_node(p_op, child_ids)

    # ---- extraction ----------------------------------------------------------

    def extract(
        self,
        root: int,
        cost_fn: Callable[[str, list[float]], float],
    ) -> Term:
        """Select min-cost e-node per class (bottom-up fixpoint), build term."""
        root = self.find(root)
        INF = float("inf")
        best_cost: dict[int, float] = {}
        best_node: dict[int, ENode] = {}
        changed = True
        rounds = 0
        while changed:
            changed = False
            rounds += 1
            if rounds > len(self.hashcons) + 10:
                break
            for cid, nodes in self.iter_classes():
                for node in sorted(nodes):  # deterministic tie-breaking
                    ccosts = [best_cost.get(self.find(c), INF) for c in node[1:]]
                    if any(c == INF for c in ccosts):
                        continue
                    c = cost_fn(node[0], ccosts)
                    if c < best_cost.get(cid, INF):
                        best_cost[cid] = c
                        best_node[cid] = node
                        changed = True
        if root not in best_node and root not in best_cost:
            raise ValueError("extraction failed: root class has no finite cost")

        def build(cid: int, depth: int = 0) -> Term:
            if depth > 10_000:
                raise RecursionError("cyclic extraction")
            node = best_node[self.find(cid)]
            return (node[0],) + tuple(build(c, depth + 1) for c in node[1:])

        return build(root)


# ---------------------------------------------------------------------------
# Rewrite driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Rewrite:
    """Internal (egglog-style) rewrite: lhs pattern → rhs pattern.

    ``guard(egraph, sub)`` may veto a match (e.g. anchor protection).
    ``compute(egraph, sub)`` may return an rhs built programmatically
    (e.g. constant folding) instead of ``rhs``.
    """

    name: str
    lhs: Term
    rhs: Optional[Term] = None
    guard: Optional[Callable] = None
    compute: Optional[Callable] = None
    bidirectional: bool = False


def run_rewrites(
    eg: EGraph,
    rewrites: list[Rewrite],
    max_iters: int = 8,
) -> int:
    """Apply internal rewrites to saturation (or node limit).  Returns the
    number of successful rule applications (for Table-3-style stats)."""
    applied = 0
    for _ in range(max_iters):
        matches: list[tuple[Rewrite, dict, int, bool]] = []
        for rw in rewrites:
            for sub, cid in eg.ematch(rw.lhs):
                if rw.guard and not rw.guard(eg, sub):
                    continue
                matches.append((rw, sub, cid, False))
            if rw.bidirectional and rw.rhs is not None:
                for sub, cid in eg.ematch(rw.rhs):
                    if rw.guard and not rw.guard(eg, sub):
                        continue
                    matches.append((rw, sub, cid, True))
        changed = False
        for rw, sub, cid, rev in matches:
            if eg.n_nodes() > eg.node_limit:
                break
            if rw.compute is not None and not rev:
                new_id = rw.compute(eg, sub)
                if new_id is None:
                    continue
            else:
                pat = rw.lhs if rev else rw.rhs
                new_id = eg.instantiate(pat, sub)
            if eg.find(new_id) != eg.find(cid):
                eg.union(new_id, cid)
                applied += 1
                changed = True
        eg.rebuild()
        if not changed or eg.n_nodes() > eg.node_limit:
            break
    return applied
