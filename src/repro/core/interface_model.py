"""Core-ISAX memory interface model (Aquas paper §4.1), adapted to TPU paths.

Each memory interface ``k`` is a 6-tuple ``(W_k, M_k, I_k, L_k, E_k, C_k)``:

    W_k : interface width in bytes (per beat)
    M_k : maximum beat count of one transaction
    I_k : maximum in-flight transactions
    L_k : read lead-off latency (cycles/beats)
    E_k : write completion cost
    C_k : cache-line size visible to that interface (bytes)

Microarchitectural constraints: a transaction of size ``m`` is legal iff
``m / W_k == 2**t <= M_k`` for some nonnegative integer ``t`` and the starting
address is aligned to ``m``.  Reads and writes pipeline independently up to
``I_k`` outstanding transactions.

The latency recurrences (paper, verbatim):

    a_j      = 1 + max(a_{j-1}, b_{j-I_k})
    b_j^ld   = m_j / W_k + max(b_{j-1}, a_j + L_k - 1)
    b_j^st   = m_j / W_k + E_k + max(b_{j-1}, a_j - 1)

with ``a_j = b_j = -1`` for ``j <= 0``.  ``b_N`` is the estimated latency of a
sequence of N same-direction transactions on interface ``k``.

On TPU, "cycles" are DMA beats: one ``hbm_vmem`` beat is 512 B at HBM bandwidth
(~819 GB/s / 1.6 GHz ≈ 512 B/cycle), in-flight transactions are concurrently
outstanding DMA copies (double/triple buffering), and C_k is the HBM burst
granularity.  The model's *decisions* (path choice, split, order) transfer; the
constants are v5e-flavoured.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Literal, Sequence


@dataclasses.dataclass(frozen=True)
class MemInterface:
    """One memory interface ``k`` as the paper's 6-tuple (plus identity/hints)."""

    name: str
    W: int  # width (bytes per beat)
    M: int  # max beats per transaction
    I: int  # max in-flight transactions
    L: int  # read lead-off latency
    E: int  # write completion cost
    C: int  # visible cache-line size (bytes)
    # TPU extension: which level of the memory hierarchy this interface reaches.
    # Smaller = closer to compute.  Used by transaction grouping (§4.3) and the
    # cache_hint machinery ("warm" data favours low levels).
    hierarchy_level: int = 1

    def __post_init__(self) -> None:
        if self.W <= 0 or self.M <= 0 or self.I <= 0:
            raise ValueError(f"interface {self.name}: W, M, I must be positive")
        if self.M & (self.M - 1):
            raise ValueError(f"interface {self.name}: M must be a power of two")

    # ---- microarchitectural constraints -------------------------------------

    def max_transaction_bytes(self) -> int:
        return self.W * self.M

    def is_legal_transaction(self, m: int, addr: int = 0) -> bool:
        """A transaction of m bytes is legal iff m/W == 2^t <= M and addr % m == 0."""
        if m <= 0 or m % self.W:
            return False
        beats = m // self.W
        if beats & (beats - 1):  # power of two
            return False
        if beats > self.M:
            return False
        return addr % m == 0

    def legal_sizes(self) -> list[int]:
        """All legal transaction sizes in decreasing order."""
        return [self.W * (1 << t) for t in range(int(math.log2(self.M)), -1, -1)]

    def decompose(self, m: int, addr: int = 0) -> list[int]:
        """Greedily split an ``m``-byte request into legal transfers, decreasing
        (paper §4.3 "greedily splits the request into legal transfer sizes of
        interface k in decreasing order").  Requests smaller than W are padded
        to one beat (hardware always moves whole beats)."""
        if m <= 0:
            return []
        # pad to beat multiple
        m = ((m + self.W - 1) // self.W) * self.W
        out: list[int] = []
        cursor = addr
        remaining = m
        for size in self.legal_sizes():
            while remaining >= size and (cursor % size == 0 or cursor == addr):
                # natural alignment: after the first (base-aligned) chunk,
                # subsequent cursors stay aligned because sizes decrease.
                if cursor % size:
                    break
                out.append(size)
                cursor += size
                remaining -= size
        if remaining:
            # fall back: emit single beats
            while remaining > 0:
                out.append(self.W)
                remaining -= self.W
        return out


Direction = Literal["load", "store"]


def sequence_latency(
    itfc: MemInterface,
    sizes: Sequence[int],
    direction: Direction = "load",
) -> int:
    """Exact latency recurrence from §4.1 for N same-direction transactions.

    Returns b_N, the completion cycle of the last transaction (cycles, with
    cycle 0 being the first issue opportunity; a_j=b_j=-1 for j<=0).
    """
    n = len(sizes)
    if n == 0:
        return 0
    a = [-1.0] * (n + 1)
    b = [-1.0] * (n + 1)
    for j in range(1, n + 1):
        m_j = sizes[j - 1]
        beats = m_j / itfc.W
        b_wait = b[j - itfc.I] if j - itfc.I >= 1 else -1.0
        a[j] = 1 + max(a[j - 1], b_wait)
        if direction == "load":
            b[j] = beats + max(b[j - 1], a[j] + itfc.L - 1)
        else:
            b[j] = beats + itfc.E + max(b[j - 1], a[j] - 1)
    return int(math.ceil(b[n]))


def approx_latency(
    itfc: MemInterface,
    op_sizes_decomposed: Sequence[Sequence[int]],
    direction: Direction = "load",
) -> float:
    """Approximation model T_k from §4.3 used inside interface selection.

        T_k^ld = L_k - 1 + Σ_q Σ_p max(L_k / I_k, m_{q,p} / W_k)
        T_k^st = Σ_q Σ_p (m_{q,p} / W_k + E_k) - 1

    where ``op_sizes_decomposed[q]`` is the legal decomposition {m_{q,p}}_p of
    operation q on this interface.  L_k/I_k simulates bubbles from the limited
    in-flight window.
    """
    if not op_sizes_decomposed:
        return 0.0
    if direction == "load":
        total = itfc.L - 1.0
        for chunks in op_sizes_decomposed:
            for m in chunks:
                total += max(itfc.L / itfc.I, m / itfc.W)
        return total
    total = -1.0
    for chunks in op_sizes_decomposed:
        for m in chunks:
            total += m / itfc.W + itfc.E
    return total


def cache_sync_penalty(itfc: MemInterface, m_q: int) -> float:
    """Second objective term of §4.3: ⌈m_q / C_k⌉ · C_k / W_k — the beat count
    needed to synchronize the touched cache lines on a hierarchy mismatch."""
    return math.ceil(m_q / itfc.C) * (itfc.C / itfc.W)


# ---------------------------------------------------------------------------
# Interface libraries
# ---------------------------------------------------------------------------

def paper_example_interfaces() -> dict[str, MemInterface]:
    """The two interfaces of the paper's Figure 2 example.

    @itfc1: instruction-extension port — low latency, 32-bit, no burst, one
            in-flight transaction.
    @itfc2: system bus — 64-bit datapath with 4-byte granularity, burst up to
            64 B, two in-flight, higher latency.  (W=4, M=16 reproduces the
            paper's Figure 4(b) canonicalization of a 108-byte request into
            64-, 32-, 8-, and 4-byte legal transfers.)
    """
    return {
        "cpuitfc": MemInterface("cpuitfc", W=4, M=1, I=1, L=2, E=1, C=64,
                                hierarchy_level=0),
        "busitfc": MemInterface("busitfc", W=4, M=16, I=2, L=6, E=2, C=64,
                                hierarchy_level=1),
    }


# v5e-flavoured constants (see DESIGN.md §3.1).
TPU_PEAK_FLOPS_BF16 = 197e12      # per chip
TPU_HBM_BW = 819e9                # bytes/s per chip
TPU_ICI_BW_PER_LINK = 50e9        # bytes/s per link (~)
TPU_VMEM_BYTES = 128 * 1024 * 1024
TPU_VMEM_BUDGET = 64 * 1024 * 1024  # usable per kernel invocation (conservative)
TPU_CLOCK_HZ = 1.6e9
MXU_DIM = 128
VPU_LANES = 8  # sublane granularity for f32


def tpu_interfaces() -> dict[str, MemInterface]:
    """TPU v5e memory-path instances of the 6-tuple model.

    hbm_vmem:  one beat = 512 B (819 GB/s / 1.6 GHz); DMA lead-off ~450 ns
               ≈ 700 cycles; up to 4 outstanding DMA copies; burst up to 512 KiB.
    vmem_vreg: on-chip load path, effectively immediate.
    ici_link:  one beat = 32 B (50 GB/s / 1.6 GHz); high lead-off (~1.25 us);
               big bursts; 4 outstanding sends.
    """
    return {
        "hbm_vmem": MemInterface("hbm_vmem", W=512, M=1024, I=4, L=700, E=64,
                                 C=512, hierarchy_level=1),
        "vmem_vreg": MemInterface("vmem_vreg", W=512, M=8, I=8, L=2, E=1,
                                  C=512, hierarchy_level=0),
        "ici_link": MemInterface("ici_link", W=32, M=4096, I=4, L=2000, E=64,
                                 C=512, hierarchy_level=2),
    }


def effective_bandwidth(
    itfc: MemInterface,
    transfer_bytes: int,
    direction: Direction = "load",
    clock_hz: float = TPU_CLOCK_HZ,
) -> float:
    """Model-predicted effective bytes/s for a single decomposed transfer —
    used by kernel_synth to compare staging strategies."""
    chunks = itfc.decompose(transfer_bytes)
    cyc = sequence_latency(itfc, chunks, direction)
    if cyc <= 0:
        return float("inf")
    return transfer_bytes * clock_hz / cyc
