"""Shared tiling arithmetic for kernel wrappers, schedulers, and dispatch.

These helpers used to live as private functions inside ``kernels/ops.py``
and were imported across module boundaries (``compile/dispatch.py``,
``pointcloud/ops.py``) under their ``_``-prefixed names.  They are the
public home now: any code that derives a launchable tile from a synthesized
schedule — domain packages in ``repro/targets``, the op wrappers, the
dispatcher — shares exactly these definitions, so the recorded schedule and
the executed schedule can never disagree on the rounding rule.
"""

from __future__ import annotations

import numpy as np


def down_pow2(n: int, cap: int) -> int:
    """Largest power-of-two divisor of ``n``, at most ``cap``.

    This is the tile-rounding rule every kernel wrapper applies to a
    synthesized block size: it always divides ``n`` (so divisibility can
    never fail), degrading toward 1-wide tiles when ``n`` has a large odd
    factor.
    """
    d = 1
    while n % (d * 2) == 0 and d * 2 <= cap:
        d *= 2
    return d


def dtype_itemsize(dtype: str) -> int:
    """Itemsize in bytes for a dtype *name*, matching ``np.dtype`` where
    possible.

    Kernel wrappers derive tiles from ``array.dtype.itemsize``; dispatch-side
    schedulers only see the dtype string in the cache key.  Using the same
    numpy resolution (with a ``bfloat16``-style width fallback for names
    numpy does not know unless ml_dtypes registered them) keeps the recorded
    schedule identical to the one the wrapper re-derives.
    """
    try:
        return np.dtype(dtype).itemsize
    except TypeError:
        return 2 if dtype.endswith("16") else 4
