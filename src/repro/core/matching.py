"""Skeleton-components pattern matching (paper §5.4).

Each ISAX is decomposed into a *skeleton* — the loop/anchor control structure
with ordering constraints — and *components* — the dataflow subtrees beneath
each anchor.  Matching proceeds in two phases:

  1. **Component tagging**: for every component we generate a tagging rule
     (the egglog-rule analogue).  When the component's subtree e-matches, the
     rule unions a unique marker e-node ``comp:<isax>:<i>`` — whose children
     record the bindings of the component's free variables in declared order —
     into the matched e-class.

  2. **Skeleton matching**: a dedicated engine walks candidate loop e-classes
     whose enclosing block satisfies the required region structure and
     contains the complete component set, then validates ordering,
     dominance/visibility, loop-carried dependences, and effect constraints.
     On success an ``isax:<name>`` e-node (children = parameter bindings in
     signature order) is unioned into the matched e-class.

Extraction with a cost model that prioritizes ISAX e-nodes then yields the
offloaded program; ``isax:<name>`` anchors become intrinsic calls (here:
``kernels/ops.py`` entry points).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import expr
from repro.core.egraph import EGraph
from repro.core.expr import Term


@dataclasses.dataclass(frozen=True)
class ISAX:
    """An ISAX definition: semantics written in the same mini-IR as software
    (the §5.1 "common abstraction level"), plus call metadata."""

    name: str
    params: tuple[str, ...]       # argument order for the intrinsic call
    term: Term                    # full semantic description (program form)
    kernel: str                   # key into the kernel/intrinsic registry
    outputs: tuple[str, ...] = () # param names written by the ISAX

    def normalized(self) -> Term:
        return expr.normalize_indices(self.term)


@dataclasses.dataclass
class Component:
    comp_id: int
    pattern: Term                 # leaves '?<name>' bind params/loop indices
    freevars: tuple[str, ...]     # marker child order
    self_dep_array: Optional[str] = None  # loop-carried accumulator array


@dataclasses.dataclass
class Skeleton:
    """Control structure of the ISAX with component placeholders.

    ``pattern`` mirrors the ISAX term but every store-value dataflow subtree
    is replaced by ``('__comp__<i>',)``.
    """

    pattern: Term
    components: list[Component]
    loop_struct: tuple | None


# ---------------------------------------------------------------------------
# Decomposition
# ---------------------------------------------------------------------------

def _pattern_of(t: Term, bindable: set[str]) -> tuple[Term, list[str]]:
    """Replace var/arr leaves whose names are bindable with pattern vars."""
    order: list[str] = []

    def rec(u: Term) -> Term:
        o = expr.op(u)
        kind = expr.leaf_kind(o)
        if kind in ("var", "arr"):
            nm = o.split(":", 1)[1]
            if nm in bindable:
                if nm not in order:
                    order.append(nm)
                return (f"?{nm}",)
            return u
        if expr.is_leaf(u):
            return u
        return (o,) + tuple(rec(c) for c in expr.children(u))

    return rec(t), order


def _arrays_read(t: Term) -> set[str]:
    out = set()
    for u in expr.walk(t):
        if expr.op(u) == "load" and len(u) > 1:
            tgt = u[1]
            if expr.op(tgt).startswith("arr:"):
                out.add(expr.op(tgt).split(":", 1)[1])
    return out


def decompose(isax: ISAX) -> Skeleton:
    """Split the ISAX term into skeleton + components (§5.4)."""
    term = isax.normalized()
    components: list[Component] = []
    bindable = set(isax.params)

    def rec(t: Term, loop_vars: tuple[str, ...]) -> Term:
        o = expr.op(t)
        if expr.is_for(t):
            idx = expr.for_index(t)
            start, end, step, body = expr.children(t)
            return (o, _skeleton_leafify(start, bindable | set(loop_vars)),
                    _skeleton_leafify(end, bindable | set(loop_vars)),
                    _skeleton_leafify(step, bindable | set(loop_vars)),
                    rec(body, loop_vars + (idx,)))
        if o == "tuple":
            return ("tuple",) + tuple(rec(c, loop_vars)
                                      for c in expr.children(t))
        if o == "store":
            arr_t = t[1]
            idx_terms = t[2:-1]
            value = t[-1]
            cid = len(components)
            free = bindable | set(loop_vars)
            pat, order = _pattern_of(value, free)
            stored_arr = (expr.op(arr_t).split(":", 1)[1]
                          if expr.op(arr_t).startswith("arr:") else None)
            self_dep = stored_arr if stored_arr in _arrays_read(value) else None
            components.append(Component(cid, pat, tuple(order), self_dep))
            arr_pat = _skeleton_leafify(arr_t, free)
            idx_pats = tuple(_skeleton_leafify(i, free) for i in idx_terms)
            return ("store", arr_pat) + idx_pats + ((f"__comp__{cid}",),)
        # other anchors (yield) — leafify dataflow beneath
        return _skeleton_leafify(t, bindable | set(loop_vars))

    pattern = rec(term, ())
    return Skeleton(pattern, components, expr.loop_structure(term))


def _skeleton_leafify(t: Term, bindable: set[str]) -> Term:
    pat, _ = _pattern_of(t, bindable)
    return pat


# ---------------------------------------------------------------------------
# Phase 1: component tagging
# ---------------------------------------------------------------------------

def tag_components(eg: EGraph, isax: ISAX, skel: Skeleton) -> int:
    """Union ``comp:<isax>:<i>`` markers into every e-class matching a
    component pattern.  Returns the number of tags inserted."""
    tags = 0
    for comp in skel.components:
        for sub, cid in eg.ematch(comp.pattern):
            child_ids = [eg.find(sub[f"?{v}"]) for v in comp.freevars]
            marker = eg.add_node(f"comp:{isax.name}:{comp.comp_id}", child_ids)
            if eg.find(marker) != eg.find(cid):
                eg.union(marker, cid)
                tags += 1
    eg.rebuild()
    return tags


# ---------------------------------------------------------------------------
# Phase 2: skeleton matching engine
# ---------------------------------------------------------------------------

class _MatchFail(Exception):
    pass


def _match_skeleton(eg: EGraph, isax: ISAX, pat: Term, cid: int,
                    sub: dict[str, int]):
    """Yield substitutions matching the skeleton pattern against e-class cid.

    Like EGraph._match_class but with component placeholders: a placeholder
    matches a class iff the class contains the corresponding marker e-node
    whose children are consistent with (or extend) the current binding.
    """
    cid = eg.find(cid)
    o = expr.op(pat)
    if o.startswith("?"):
        bound = sub.get(o)
        if bound is None:
            s2 = dict(sub)
            s2[o] = cid
            yield s2
        elif eg.find(bound) == cid:
            yield sub
        return
    if o.startswith("__comp__"):
        comp_id = int(o[len("__comp__"):])
        comp = _COMP_CACHE[(isax.name, comp_id)]
        marker_op = f"comp:{isax.name}:{comp_id}"
        for node in eg.nodes_of(cid):
            if node[0] != marker_op:
                continue
            s2 = dict(sub)
            ok = True
            for v, child in zip(comp.freevars, node[1:]):
                key = f"?{v}"
                child = eg.find(child)
                if key in s2 and eg.find(s2[key]) != child:
                    ok = False
                    break
                s2[key] = child
            if ok:
                yield s2
        return
    for node in list(eg.nodes_of(cid)):
        if node[0] != o or len(node) - 1 != len(expr.children(pat)):
            continue
        yield from _match_children(eg, isax, expr.children(pat), node[1:], sub)


def _match_children(eg, isax, pats, cids, sub):
    if not pats:
        yield sub
        return
    for s in _match_skeleton(eg, isax, pats[0], cids[0], sub):
        yield from _match_children(eg, isax, pats[1:], cids[1:], s)


_COMP_CACHE: dict[tuple[str, int], Component] = {}


def _reachable(eg: EGraph, src: int, dst: int, limit: int = 10_000) -> bool:
    """Is class dst reachable from src through e-node children?"""
    src, dst = eg.find(src), eg.find(dst)
    seen = {src}
    stack = [src]
    steps = 0
    while stack:
        steps += 1
        if steps > limit:
            return True  # conservative
        c = stack.pop()
        if c == dst:
            return True
        for node in eg.nodes_of(c):
            for ch in node[1:]:
                ch = eg.find(ch)
                if ch not in seen:
                    seen.add(ch)
                    stack.append(ch)
    return False


def _validate(eg: EGraph, isax: ISAX, skel: Skeleton, sub: dict[str, int],
              root_cid: int) -> None:
    """§5.4 checks: ordering, dominance/visibility, loop-carried deps, effects.

    Ordering and effect constraints are structural: the skeleton pattern pins
    the anchor sequence and arity of every tuple e-node, so any match already
    satisfies them.  The remaining semantic checks:
    """
    # Dominance/visibility: no bound argument may contain the matched region
    # itself (a binding that cycles back into the loop is not a valid operand).
    for name, cid in sub.items():
        if eg.find(cid) == eg.find(root_cid):
            raise _MatchFail(f"binding {name} is the matched region itself")
        for node in eg.nodes_of(cid):
            if node[0].startswith("isax:"):
                continue
            # arguments must not structurally contain the candidate loop
        if _reachable_via_anchors(eg, cid, root_cid):
            raise _MatchFail(f"binding {name} not visible before the region")
    # Loop-carried dependences: accumulator arrays must match the skeleton's
    # self-dependence shape — the bound class for a self-dep array must be
    # read inside its own component marker (checked during decompose) and the
    # same binding must be used for the store target (already enforced by
    # shared pattern vars).  Distinct non-self-dep stores must bind distinct
    # arrays (no accidental aliasing).
    outs = [f"?{c}" for c in isax.outputs if f"?{c}" in sub]
    if len({eg.find(sub[o]) for o in outs}) != len(outs):
        raise _MatchFail("aliased output bindings")


def _reachable_via_anchors(eg: EGraph, src: int, dst: int) -> bool:
    """True if src's dataflow *requires* the candidate region (dst) — i.e. the
    region stores into something src loads and src is only producible after
    it.  Conservative approximation: src reaches dst through child edges."""
    return _reachable(eg, src, dst) and eg.find(src) != eg.find(dst)


@dataclasses.dataclass
class MatchResult:
    isax: str
    root_class: int
    bindings: dict[str, int]


def match_isax(eg: EGraph, isax: ISAX,
               skel: Skeleton | None = None) -> list[MatchResult]:
    """Run both phases for one ISAX over the whole e-graph; insert ``isax:``
    markers for every validated match."""
    skel = skel or decompose(isax)
    for comp in skel.components:
        _COMP_CACHE[(isax.name, comp.comp_id)] = comp
    tag_components(eg, isax, skel)

    results: list[MatchResult] = []
    seen_roots: set[int] = set()
    # candidate roots: classes containing a loop e-node of the right op
    root_op = expr.op(skel.pattern)
    for cid, nodes in list(eg.iter_classes()):
        if not any(n[0] == root_op for n in nodes):
            continue
        for sub in _match_skeleton(eg, isax, skel.pattern, cid, {}):
            try:
                _validate(eg, isax, skel, sub, cid)
            except _MatchFail:
                continue
            missing = [p for p in isax.params if f"?{p}" not in sub]
            if missing:
                continue
            root = eg.find(cid)
            if root in seen_roots:
                break
            seen_roots.add(root)
            child_ids = [eg.find(sub[f"?{p}"]) for p in isax.params]
            marker = eg.add_node(f"isax:{isax.name}", child_ids)
            eg.union(marker, cid)
            eg.rebuild()
            results.append(MatchResult(isax.name, root, dict(sub)))
            break
    return results
