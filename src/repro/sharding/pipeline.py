"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

Completes the at-scale parallelism set (DP/FSDP/TP/EP/SP + **PP**): layer
stacks are split into S stages laid out along a mesh axis; microbatches
circulate stage-to-stage with ``jax.lax.ppermute`` in the classic GPipe
schedule (S + M − 1 ticks, bubble fraction (S−1)/(S+M−1)).  Differentiable —
``jax.grad`` through ``ppermute`` yields the reverse permute, so the same
function serves training.

Use when layer count divides the stage count (e.g. yi-9b / internlm2: 48
layers over 16 stages).  The dry-run lowers this on the production mesh via
``launch/perf.py --variant pp`` (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def gpipe(
    stage_fn: Callable,            # (stage_params, x: (mb, S, d)) -> same
    mesh: Mesh,
    stage_axis: str = "model",
    data_axes: tuple[str, ...] = ("data",),
):
    """Returns pipelined(params_stacked, x_microbatches) running under
    shard_map.

    params_stacked : pytree with leading dim L = n_stages * layers_per_stage
                     (sharded over ``stage_axis`` on that dim)
    x_microbatches : (n_micro, micro_batch, seq, d) (microbatch dim sharded
                     over ``data_axes``)

    Output: (n_micro, micro_batch, seq, d) — activations after all stages
    (each microbatch has passed through every layer, in order).
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[stage_axis]

    def run(params_local, x_local):
        # params_local: leading dim L/S (this stage's layers)
        # x_local: (n_micro, mb_local, seq, d)
        stage = jax.lax.axis_index(stage_axis)
        n_micro = x_local.shape[0]
        ticks = n_micro + n_stages - 1
        mb_shape = x_local.shape[1:]

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t (when valid); others take the
            # circulated activation from the previous stage.
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inject = x_local[mb_idx]
            inp = jnp.where(stage == 0, inject, state)
            out = stage_fn(params_local, inp)
            # last stage emits microbatch (t - (S-1)) at tick t
            emit_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            valid = (t >= n_stages - 1)
            outputs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, emit_idx, 0),
                lambda o: o,
                outputs)
            # circulate: stage i -> stage i+1 (last wraps, value unused)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            state = jax.lax.ppermute(out, stage_axis, perm)
            return (state, outputs), None

        state0 = jnp.zeros(mb_shape, x_local.dtype)
        outs0 = jnp.zeros_like(x_local)
        (_, outputs), _ = jax.lax.scan(
            tick, (state0, outs0), jnp.arange(ticks))
        # only the LAST stage holds real outputs; broadcast them so the
        # result is replicated along the stage axis (psum of masked values).
        is_last = (stage == n_stages - 1).astype(outputs.dtype)
        outputs = jax.lax.psum(outputs * is_last, stage_axis)
        return outputs

    in_specs = (P(stage_axis), P(None, data_axes, None, None))
    out_specs = P(None, data_axes, None, None)
    return shard_map(run, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def reference_forward(stage_fn, params_stacked, x_micro, n_stages: int):
    """Oracle: apply all stages sequentially (no pipelining)."""
    L = jax.tree.leaves(params_stacked)[0].shape[0]
    per = L // n_stages

    def apply_all(x):
        for s in range(n_stages):
            p = jax.tree.map(lambda a: a[s * per:(s + 1) * per],
                             params_stacked)
            x = stage_fn(p, x)
        return x

    return jax.vmap(apply_all)(x_micro)
