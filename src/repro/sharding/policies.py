"""Sharding policies: logical parameter/activation axes → mesh axes.

Mesh axes: ('data', 'model') single-pod, ('pod', 'data', 'model') multi-pod.

Policy 'fsdp_tp' (default):
  * every ≥1-D weight is sharded over 'data' on its 'embed' axis (ZeRO-3
    style full parameter+optimizer sharding),
  * 'heads'/'ff'/'experts'/'vocab'/'ssm_in' shard over 'model' (TP/EP),
  * axes that don't divide the mesh axis fall back to replication
    (e.g. granite's vocab 49155 is odd → vocab unsharded).

Activations: batch over ('pod','data') (pure DP across pods), model-parallel
dims over 'model'; decode KV caches shard batch over data and kv-heads (or
head_dim, or sequence for batch-1 long-context) over 'model'.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# priority lists per logical axis: first mesh axis that divides wins
PARAM_RULES: dict[str, tuple[str, ...]] = {
    "layers": (),
    "vocab": ("model",),
    "embed": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),          # fallback TP axis for GQA handled in resolve()
    "ff": ("model",),
    "experts": ("model",),
    "ssm_in": ("model",),
    "ssm_heads": ("model",),
    "conv": (),
    "state": (),
}

# Policy presets (hillclimb variants — §Perf in EXPERIMENTS.md):
#   fsdp_tp  — ZeRO-3 over 'data' + TP/EP over 'model' (baseline)
#   dp_only  — params replicated, batch over BOTH axes (pure 256-way DP;
#              wins for small models where TP+FSDP collectives dominate)
#   fsdp_2d  — params sharded over both axes on the same dim where possible
POLICIES: dict[str, dict[str, tuple[str, ...]]] = {
    "fsdp_tp": PARAM_RULES,
    "fsdp_tp_hd": PARAM_RULES,   # + GQA head_dim TP fallback (see below)
    "dp_only": {ax: () for ax in PARAM_RULES},
    "fsdp_2d": {**PARAM_RULES, "embed": (("data", "model"), "data")},
    # Serving: no optimizer state, tiny activations — shard weight
    # CONTRACTION dims 2-D (embed→model, ff→data, experts→model) so decode
    # pays small activation all-reduces instead of full FSDP weight gathers
    # (arctic decode: 3×1.1 GB f32 gathers/layer → §Perf addendum).
    "serve": {
        "layers": (), "vocab": ("model",), "embed": ("model", "data"),
        "heads": (), "kv_heads": (), "head_dim": (), "ff": ("data",),
        "experts": ("model",), "ssm_in": ("model",), "ssm_heads": ("model",),
        "conv": (), "state": (),
    },
}

# batch/activation DP axes per policy (model axis joins DP for dp_only)
POLICY_DP: dict[str, tuple[str, ...]] = {
    "fsdp_tp": ("data",),
    "fsdp_tp_hd": ("data",),
    "dp_only": ("data", "model"),
    "fsdp_2d": ("data",),
    "serve": ("data",),
}


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def _fits(mesh: Mesh, dim: int, mesh_axis) -> bool:
    if isinstance(mesh_axis, tuple):
        size = 1
        for a in mesh_axis:
            if a not in mesh.axis_names:
                return False
            size *= _axis_size(mesh, a)
        return dim % size == 0
    return (mesh_axis in mesh.axis_names
            and dim % _axis_size(mesh, mesh_axis) == 0)


def resolve_param_spec(axes: tuple, shape: tuple, mesh: Mesh,
                       policy: str = "fsdp_tp") -> P:
    """Map one parameter's logical axes to a PartitionSpec.

    Embedding/unembedding tables are vocab-parallel only (Megatron style):
    sharding their d_model axis over 'data' makes the unembed contraction
    dim and the batch dim compete for the same mesh axis, which GSPMD
    resolves by replicating the batch and all-gathering full logits."""
    rules = POLICIES[policy]
    spec: list = []
    used: set = set()
    vocab_table = "vocab" in axes
    for ax_name, dim in zip(axes, shape):
        if vocab_table and ax_name == "embed":
            spec.append(None)
            continue
        chosen = None
        for mesh_axis in rules.get(ax_name, ()):
            names = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
            if (_fits(mesh, dim, mesh_axis)
                    and not (set(names) & used)):
                chosen = mesh_axis
                break
        spec.append(chosen)
        if chosen:
            used.update(chosen if isinstance(chosen, tuple) else (chosen,))
    # GQA head_dim TP fallback — OPT-IN ONLY ('fsdp_tp_hd').  Sharding
    # head_dim puts the QKᵀ contraction dim on 'model', turning every
    # attention score tensor into a partial-sum all-reduce of the full
    # (…, S, T) matrix (measured: 3×60 GB per layer on arctic-480b train_4k
    # — see EXPERIMENTS.md §Perf iteration 2).  Replicating attention over
    # 'model' is strictly cheaper when neither heads nor kv_heads divide.
    if (policy == "fsdp_tp_hd" and "kv_heads" in axes
            and "model" not in used and "head_dim" in axes):
        i = axes.index("head_dim")
        if shape[i] % _axis_size(mesh, "model") == 0:
            spec[i] = "model"
    return P(*spec)


def param_shardings(cfg: ModelConfig, mesh: Mesh, axes_tree, params_tree,
                    policy: str = "fsdp_tp"):
    """Pytree of NamedShardings matching params (axes_tree mirrors shapes)."""
    def one(axes, leaf):
        return NamedSharding(mesh, resolve_param_spec(axes, leaf.shape, mesh,
                                                      policy))
    return jax.tree.map(one, axes_tree, params_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def dp_axes(mesh: Mesh, policy: str = "fsdp_tp") -> tuple[str, ...]:
    base = POLICY_DP.get(policy, ("data",))
    return (("pod",) + base) if "pod" in mesh.axis_names else base


def _div(dim: int, mesh: Mesh, axes: tuple[str, ...]) -> Optional[tuple]:
    size = 1
    for a in axes:
        size *= _axis_size(mesh, a)
    return axes if dim % size == 0 else None


def batch_sharding(cfg: ModelConfig, mesh: Mesh, batch_specs: dict,
                   policy: str = "fsdp_tp") -> dict:
    """Shardings for a train/prefill batch dict."""
    dp = dp_axes(mesh, policy)
    out = {}
    for k, sds in batch_specs.items():
        b = sds.shape[0]
        dpa = _div(b, mesh, dp) or _div(b, mesh, ("data",))
        lead = dpa if dpa else None
        rest = (None,) * (len(sds.shape) - 1)
        out[k] = NamedSharding(mesh, P(lead, *rest))
    return out


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_specs,
                    seq_axis_ok: bool = True, policy: str = "fsdp_tp"):
    """Decode-cache shardings.  KV caches (L,B,T,K,hd): batch→data,
    kv-heads→model (or head_dim, or — batch==1 long-context — T→data and
    heads→model).  SSM states (L,B,H,N,P): batch→data, H→model."""
    dp = dp_axes(mesh)

    def one(path_hint, sds):
        shp = sds.shape
        if path_hint in ("k", "v"):  # (L|sites, B, T, K, hd) KV cache
            _, b, t, k, hd = shp
            dpa = _div(b, mesh, dp) or _div(b, mesh, ("data",))
            kv_ax = "model" if k % _axis_size(mesh, "model") == 0 else None
            # When kv heads don't divide the model axis, shard the SEQUENCE
            # over 'model' (sequence-parallel KV): attention over the sharded
            # T reduces with tiny (B,H,1) max/sum collectives.  Never shard
            # head_dim — that makes every score tensor a partial-sum
            # all-reduce (§Perf granite-decode iteration 1).
            t_ax = None
            if kv_ax is None and t % _axis_size(mesh, "model") == 0:
                t_ax = "model"
            elif (dpa is None and seq_axis_ok
                  and t % _axis_size(mesh, "data") == 0):
                t_ax = "data"  # batch-1 long context: SP over data instead
            return NamedSharding(mesh, P(None, dpa, t_ax, kv_ax, None))
        if path_hint == "state":  # (L,B,H,N,P) SSM state
            _, b, h, n, p = shp
            dpa = _div(b, mesh, dp) or _div(b, mesh, ("data",))
            h_ax = "model" if h % _axis_size(mesh, "model") == 0 else None
            return NamedSharding(mesh, P(None, dpa, h_ax, None, None))
        if path_hint == "conv":  # (L,B,w-1,ch)
            _, b, _, ch = shp
            dpa = _div(b, mesh, dp) or _div(b, mesh, ("data",))
            ch_ax = "model" if ch % _axis_size(mesh, "model") == 0 else None
            return NamedSharding(mesh, P(None, dpa, None, ch_ax))
        if path_hint == "enc_out":  # (B, T, d)
            dpa = _div(shp[0], mesh, dp) or _div(shp[0], mesh, ("data",))
            return NamedSharding(mesh, P(dpa, None, None))
        return NamedSharding(mesh, P(*([None] * len(shp))))

    def walk(tree, hint=""):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        return one(hint, tree)

    return walk(cache_specs)


def activation_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                     policy: str = "fsdp_tp") -> dict:
    """PartitionSpecs for block-boundary activation constraints.

    'btd' — (batch, seq, d_model): batch over DP axes.
    'btv' — logits (batch, seq, vocab): batch over DP, vocab over model if
            divisible.
    Falls back to None entries when the batch doesn't divide DP (batch-1
    long-context decode)."""
    dp = dp_axes(mesh, policy)
    dpa = _div(global_batch, mesh, dp) or _div(global_batch, mesh, ("data",))
    v_ax = ("model" if cfg.vocab % _axis_size(mesh, "model") == 0
            and "model" not in (dpa or ()) else None)
    moe = {}
    if cfg.moe is not None:
        e_ax = ("model" if cfg.moe.n_experts % _axis_size(mesh, "model") == 0
                else None)
        # capacity/token dims shard over 'data' only (sizes are derived from
        # the token count, divisible by the data axis but not necessarily by
        # pod×data)
        moe = {"ecd": P(e_ax, "data", None), "td": P("data", None),
               # grouped (GShard) dispatch: groups follow data, experts model
               "gtec": P("data", None, e_ax, None),
               "gecd": P("data", e_ax, None, None)}
    if dpa is None:
        return {"btd": None,
                "btv": P(None, None, v_ax) if v_ax else None, **moe}
    return {
        "btd": P(dpa, None, None),
        "btv": P(dpa, None, v_ax),
        **moe,
    }


def ssm_state_sharding(mesh: Mesh, sds) -> NamedSharding:
    """(L,B,H,N,P): batch→data, heads→model."""
    dp = dp_axes(mesh)
    _, b, h, n, p = sds.shape
    dpa = _div(b, mesh, dp) or _div(b, mesh, ("data",))
    h_ax = "model" if h % _axis_size(mesh, "model") == 0 else None
    return NamedSharding(mesh, P(None, dpa, h_ax, None, None))
