"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596].

12L d_model=1024 16H (MHA) d_ff=4096 vocab=256206.  Encoder-decoder: 12
encoder + 12 decoder layers.  The audio frontend (fbank/w2v-BERT) is a stub
per assignment; ``input_specs`` provides precomputed frame embeddings
(n_prefix_tokens frames) to the encoder.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,          # per stack: 12 enc + 12 dec
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    n_prefix_tokens=1024,  # encoder frame positions (stub embeddings)
)
