"""paligemma-3b [vlm] — SigLIP + gemma backbone [arXiv:2407.07726].

18L d_model=2048 8H (GQA kv=1 → MQA) d_ff=16384 vocab=257216.
The SigLIP vision frontend is a stub per assignment: ``input_specs`` provides
256 precomputed patch embeddings per image, prepended with a prefix-LM mask.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    head_dim=256,
    n_prefix_tokens=256,
    tie_embeddings=True,
)
