"""llama110m — the paper's own §6.5 case study: Llama-2 architecture at 110M
parameters, 8-bit weight quantization, for edge LLM inference (TTFT/ITL).

Dimensions follow llama2.c's 110M config: 12L d_model=768 12H d_ff=2048.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama110m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=2048,
    vocab=32000,
    param_dtype="float32",
    compute_dtype="float32",
    remat="none",
)
