"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

38L d_model=2048 32H (GQA kv=32 → MHA) d_ff=8192 vocab=32000, ssm_state=64.
One shared transformer block (attention + MLP, single weight set) is applied
every 6 Mamba2 layers — the zamba2 weight-sharing scheme.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    head_dim=64,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=256),
    shared_attn_every=6,
    tie_embeddings=True,
)
