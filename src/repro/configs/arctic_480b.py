"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2 with a
parallel dense residual MLP per layer.  Full-sharding (ZeRO-3 over data×pod,
EP over model) and full remat are required to fit 256 chips — see DESIGN.md.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    moe=MoEConfig(n_experts=128, top_k=2, capacity_factor=1.25,
                  dense_residual_ff=4864, dispatch="grouped"),
    remat="full",
)
