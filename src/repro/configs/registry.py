"""Architecture config registry: ``get_config("granite-3-8b")`` etc."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

_MODULES = {
    "paligemma-3b": "repro.configs.paligemma_3b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "yi-9b": "repro.configs.yi_9b",
    "qwen1.5-0.5b": "repro.configs.qwen15_05b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "mamba2-2.7b": "repro.configs.mamba2_27b",
    "arctic-480b": "repro.configs.arctic_480b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "zamba2-1.2b": "repro.configs.zamba2_12b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "llama110m": "repro.configs.llama110m",
}

ASSIGNED_ARCHS = [k for k in _MODULES if k != "llama110m"]


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {k: get_config(k) for k in _MODULES}


def available_configs() -> list[str]:
    """Registered architecture names (public home of the old ``_MODULES``
    keys, which tests used to import privately)."""
    return list(_MODULES)
