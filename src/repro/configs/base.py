"""Model / run configuration system.

Every assigned architecture is a ``ModelConfig`` in ``configs/<id>.py``;
``configs.registry.get_config(name)`` resolves them.  Input shapes are the
assignment's four LM shape cells plus per-family skips (see DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    dense_residual_ff: int = 0   # arctic: parallel dense FFN next to MoE
    # dispatch: 'sort' (argsort + scatter; minimal FLOPs, but its scatter is
    # unshardable under GSPMD) or 'grouped' (GShard one-hot einsum —
    # shardable; ~2% dispatch FLOP overhead).  See EXPERIMENTS.md §Perf.
    dispatch: str = "sort"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2              # d_inner = expand * d_model
    chunk: int = 256             # SSD chunk length
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 → d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every k-th layer
    shared_attn_every: int = 0
    # encdec: layers are split n_layers enc + n_layers dec
    # vlm / audio: number of stub-frontend prefix embeddings per example
    n_prefix_tokens: int = 0
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    logit_dtype: str = "float32"
    # remat policy: 'none' | 'dots' | 'full'
    remat: str = "dots"
    norm_eps: float = 1e-6

    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM/hybrid run long_500k; pure attention
        archs skip it (see DESIGN.md §4)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs generate tokens (encdec has a decoder)

    def n_params(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, ff, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.resolved_head_dim()
        qkv = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads
        o = hd * self.n_heads * d
        attn = qkv + o
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            per_layer = (d * (2 * d_in + 2 * s.d_state + d_in // s.head_dim)
                         + d_in * d + d_in * s.conv_width)
            body = L * per_layer
        elif self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            ssm_per = (d * (2 * d_in + 2 * s.d_state + d_in // s.head_dim)
                       + d_in * d + d_in * s.conv_width)
            shared = attn + 3 * d * ff  # one shared block
            body = L * ssm_per + shared
        elif self.family == "moe":
            mlp = 3 * d * ff * self.moe.n_experts
            mlp += 3 * d * self.moe.dense_residual_ff
            body = L * (attn + mlp + d * self.moe.n_experts)
        elif self.family == "encdec":
            body = L * (attn + 3 * d * ff) + L * (2 * attn + 3 * d * ff)
        else:
            body = L * (attn + 3 * d * ff)
        emb = V * d * (1 if self.tie_embeddings else 2)
        return body + emb

    def n_active_params(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.n_params()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        hd = self.resolved_head_dim()
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + hd * self.n_heads * d
        mlp = 3 * d * ff * self.moe.top_k + 3 * d * self.moe.dense_residual_ff
        body = L * (attn + mlp + d * self.moe.n_experts)
        return body + self.vocab * self.d_model * 2


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[ShapeConfig]:
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue  # quadratic-attention skip, recorded in DESIGN.md §4
        out.append(s)
    return out


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test configuration of the same family: tiny depth/width/experts/
    vocab, preserving every structural feature (GQA ratio, bias, MoE top-k,
    SSM state, shared-attn period, prefix tokens)."""
    kv_ratio = max(1, cfg.n_heads // max(1, cfg.n_kv_heads))
    n_heads = 4
    n_kv = max(1, n_heads // kv_ratio)
    moe = None
    if cfg.moe:
        moe = MoEConfig(n_experts=min(8, cfg.moe.n_experts),
                        top_k=min(cfg.moe.top_k, 2),
                        capacity_factor=cfg.moe.capacity_factor,
                        dense_residual_ff=64 if cfg.moe.dense_residual_ff else 0,
                        dispatch=cfg.moe.dispatch)
    ssm = None
    if cfg.ssm:
        ssm = SSMConfig(d_state=16, head_dim=8, expand=2, chunk=16,
                        conv_width=cfg.ssm.conv_width)
    return dataclasses.replace(
        cfg,
        n_layers=2 if cfg.shared_attn_every == 0 else 4,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_ff=128,
        vocab=512,
        head_dim=16,
        moe=moe,
        ssm=ssm,
        shared_attn_every=min(cfg.shared_attn_every, 2) if cfg.shared_attn_every else 0,
        n_prefix_tokens=min(cfg.n_prefix_tokens, 4),
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )
