"""mamba2-2.7b [ssm] — SSD state-space duality [arXiv:2405.21060].

64L d_model=2560 attention-free, vocab=50280, ssm_state=128.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    tie_embeddings=True,
)
