"""Mamba2 / SSD (state-space duality) stack [arXiv:2405.21060].

The SSD layer computes, per head h with state size N and head dim P:

    h_t = exp(dt_t·A) · h_{t-1} + dt_t · B_t ⊗ x_t        (N×P state)
    y_t = C_t · h_t + D · x_t

Training uses the chunked SSD algorithm: intra-chunk attention-like masked
matmuls (MXU-friendly — the "duality") + an inter-chunk scan over chunk
states.  Decoding is the O(1) recurrent step.  The chunk length comes from
``core.kernel_synth.choose_ssd_blocks`` (interface-aware synthesis); the
Pallas ``ssd_scan`` kernel implements the same chunk step for TPU.

This family is attention-free: the paper's flash-attention ISAX is
inapplicable (DESIGN.md §4); the SSD chunk step is the ISAX analogue.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compile.config import LoweringConfig, default_lowering
from repro.configs.base import ModelConfig
from repro.models import layers as L


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    return d_in, H, s.d_state, s.head_dim


def init_ssm_block(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    d_in, H, N, P = _dims(cfg)
    conv_ch = d_in + 2 * N
    dt = L.dtype_of(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm": L.init_rmsnorm(d, dt),
        "in_proj": (jax.random.normal(k1, (d, 2 * d_in + 2 * N + H))
                    * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(k2, (s.conv_width, conv_ch))
                   * s.conv_width ** -0.5).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dtype=dt),
        "A_log": jnp.zeros((H,), dtype=jnp.float32),
        "D": jnp.ones((H,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((H,), dtype=jnp.float32),
        "gate_norm": L.init_rmsnorm(d_in, dt),
        "out_proj": (jax.random.normal(k3, (d_in, d)) * d_in ** -0.5
                     ).astype(dt),
    }


def ssm_block_axes(cfg: ModelConfig) -> dict:
    return {
        "norm": L.rmsnorm_axes(),
        "in_proj": ("embed", "ssm_in"),
        "conv_w": ("conv", "ssm_in"),
        "conv_b": ("ssm_in",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "gate_norm": {"scale": ("ssm_in",)},
        "out_proj": ("ssm_in", "embed"),
    }


# ---------------------------------------------------------------------------
# SSD chunked scan (training / prefill)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, chunk: int):
    """x: (b,s,H,P), dt: (b,s,H), A: (H,) negative, B/C: (b,s,N).

    Returns y: (b,s,H,P).  Sequences not divisible by `chunk` are padded with
    dt=0 positions (zero contribution, unit decay) and sliced back.
    """
    b, s, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, s)
    s_orig = s
    if s % Q:
        pad = Q - s % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // Q
    xc = x.reshape(b, nc, Q, H, P)
    dtc = dt.reshape(b, nc, Q, H)
    Bc = B.reshape(b, nc, Q, N)
    Cc = C.reshape(b, nc, Q, N)

    a = dtc * A  # (b,nc,Q,H), negative increments
    a_cum = jnp.cumsum(a, axis=2)

    # intra-chunk: Y[q] = Σ_{k<=q} (C_q·B_k)·exp(acum_q - acum_k)·dt_k·x_k
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)
    decay = jnp.exp(a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :])
    tril = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    M = scores[..., None] * jnp.where(tril, decay, 0.0)  # (b,c,q,k,H)
    y_intra = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", M, dtc, xc)

    # chunk states: S_c = Σ_k exp(acum_last - acum_k)·dt_k·B_k⊗x_k  (b,c,H,N,P)
    decay_last = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (b,c,Q,H)
    states = jnp.einsum("bckh,bckh,bckn,bckhp->bchnp",
                        decay_last, dtc, Bc, xc)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (b,c,H)

    def scan_body(h_prev, inp):
        s_c, dec = inp  # (b,H,N,P), (b,H)
        h_new = dec[:, :, None, None] * h_prev + s_c
        return h_new, h_prev

    h0 = jnp.zeros((b, H, N, P), dtype=x.dtype)
    _, h_prevs = jax.lax.scan(
        scan_body, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # (b,c,H,N,P)

    # inter-chunk contribution: Y[q] += (C_q · h_prev) · exp(acum_q)
    y_inter = jnp.einsum("bcqn,bchnp,bcqh->bcqhp",
                         Cc, h_prevs, jnp.exp(a_cum))
    return (y_intra + y_inter).reshape(b, s, H, P)[:, :s_orig]


def _causal_conv(xBC, w, bias):
    """Depthwise causal conv1d.  xBC: (b,s,ch), w: (width,ch)."""
    width = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(width))
    return out + bias


def ssm_block(params, u, cfg: ModelConfig, collect_cache: bool = False,
              lowering: Optional[LoweringConfig] = None):
    """Full-sequence SSD block.  u: (b,s,d).  Returns (out, cache|None)."""
    lw = lowering or default_lowering()
    s_cfg = cfg.ssm
    d_in, H, N, P = _dims(cfg)
    cd = L.dtype_of(cfg.compute_dtype)
    x_res = u
    u = L.rmsnorm(params["norm"], u, cfg.norm_eps, lowering=lw).astype(cd)
    proj = u @ params["in_proj"].astype(cd)  # (b,s,2*d_in+2N+H)
    z, xBC, dt_raw = jnp.split(proj, [d_in, 2 * d_in + 2 * N], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"].astype(cd),
                                   params["conv_b"].astype(cd)))
    x, B, C = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    b, s, _ = x.shape
    xh = x.reshape(b, s, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    rec = lw.lower("ssd_scan", (b, s, H, P, N), jnp.float32)
    if rec.impl == "isax":
        # kernel layout is (b, H, s, P) / (b, H, s); transpose in and out
        y = rec.kernel_fn(
            xh.astype(jnp.float32).transpose(0, 2, 1, 3),
            dt.transpose(0, 2, 1), A,
            B.astype(jnp.float32), C.astype(jnp.float32),
            interpret=lw.interpret).transpose(0, 2, 1, 3)
    else:
        y = ssd_chunked(xh.astype(jnp.float32), dt, A,
                        B.astype(jnp.float32), C.astype(jnp.float32),
                        s_cfg.chunk)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(cd)
    y = L.rmsnorm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps,
                  lowering=lw)
    out = x_res + (y @ params["out_proj"].astype(cd)).astype(x_res.dtype)

    cache = None
    if collect_cache:
        # final recurrent state + pre-conv tail for decode continuation
        width = s_cfg.conv_width
        state = _final_state(xh.astype(jnp.float32), dt, A,
                             B.astype(jnp.float32), s_cfg.chunk)
        cache = {"conv": proj[:, -(width - 1):, d_in:2 * d_in + 2 * N],
                 "state": state}
    return out, cache


def _final_state(x, dt, A, B, chunk: int):
    b, s, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, s)
    if s % Q:  # dt=0 padding: no contribution, unit decay
        pad = Q - s % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // Q
    xc = x.reshape(b, nc, Q, H, P)
    dtc = dt.reshape(b, nc, Q, H)
    Bc = B.reshape(b, nc, Q, N)
    a_cum = jnp.cumsum(dtc * A, axis=2)
    decay_last = jnp.exp(a_cum[:, :, -1:, :] - a_cum)
    states = jnp.einsum("bckh,bckh,bckn,bckhp->bchnp", decay_last, dtc, Bc, xc)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])

    def body(h, inp):
        s_c, dec = inp
        return dec[:, :, None, None] * h + s_c, None

    h0 = jnp.zeros((b, H, N, P), dtype=x.dtype)
    h, _ = jax.lax.scan(body, h0, (states.transpose(1, 0, 2, 3, 4),
                                   chunk_decay.transpose(1, 0, 2)))
    return h


def ssm_block_decode(params, u, cfg: ModelConfig, cache,
                     lowering: Optional[LoweringConfig] = None):
    """O(1) recurrent step (no dispatch: the recurrence has no ISAX-shaped
    loop to offload).  u: (b,1,d); cache: {'conv': (b,w-1,ch),
    'state': (b,H,N,P)}.  Returns (out, new_cache)."""
    lw = lowering or default_lowering()
    s_cfg = cfg.ssm
    d_in, H, N, P = _dims(cfg)
    cd = L.dtype_of(cfg.compute_dtype)
    x_res = u
    u = L.rmsnorm(params["norm"], u, cfg.norm_eps, lowering=lw).astype(cd)
    proj = (u @ params["in_proj"].astype(cd))[:, 0]  # (b, 2d_in+2N+H)
    z, xBC_new, dt_raw = (proj[:, :d_in], proj[:, d_in:2 * d_in + 2 * N],
                          proj[:, 2 * d_in + 2 * N:])
    conv_hist = jnp.concatenate(
        [cache["conv"].astype(cd), xBC_new[:, None, :]], axis=1)  # (b,w,ch)
    w = params["conv_w"].astype(cd)
    xBC = jax.nn.silu(jnp.einsum("bwc,wc->bc", conv_hist, w)
                      + params["conv_b"].astype(cd))
    x, B, C = (xBC[:, :d_in], xBC[:, d_in:d_in + N], xBC[:, d_in + N:])
    xh = x.reshape(-1, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)  # (b,H)
    state = cache["state"]
    state = (decay[:, :, None, None] * state
             + jnp.einsum("bh,bn,bhp->bhnp", dt, B.astype(jnp.float32), xh))
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), state)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(-1, 1, d_in).astype(cd)
    y = L.rmsnorm(params["gate_norm"], y * jax.nn.silu(z[:, None, :]),
                  cfg.norm_eps, lowering=lw)
    out = x_res + (y @ params["out_proj"].astype(cd)).astype(x_res.dtype)
    return out, {"conv": conv_hist[:, 1:, :].astype(cache["conv"].dtype),
                 "state": state}


# ---------------------------------------------------------------------------
# Full model (pure SSM stack)
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    ke, kl = jax.random.split(key)
    keys = jax.random.split(kl, cfg.n_layers)
    blocks = jax.vmap(lambda k: init_ssm_block(cfg, k))(keys)
    return {
        "embed": L.init_embedding(cfg, ke),
        "blocks": blocks,
        "final_norm": L.init_rmsnorm(cfg.d_model,
                                     L.dtype_of(cfg.param_dtype)),
    }


def param_axes(cfg: ModelConfig) -> dict:
    stack = jax.tree.map(lambda ax: ("layers",) + ax, ssm_block_axes(cfg),
                         is_leaf=lambda x: isinstance(x, tuple))
    return {"embed": L.embedding_axes(), "blocks": stack,
            "final_norm": L.rmsnorm_axes()}


def loss(params, batch, cfg: ModelConfig,
         lowering: Optional[LoweringConfig] = None):
    lw = lowering or default_lowering()
    x = L.embed(params["embed"], batch["tokens"], cfg)

    def body(h, bp):
        h2, _ = ssm_block(bp, L.shard_act(h, "btd"), cfg, lowering=lw)
        return h2, None

    body = L.remat_wrap(body, cfg.remat)
    h, _ = jax.lax.scan(body, x, params["blocks"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps, lowering=lw)
    logits = L.unembed(params["embed"]["table"], h, cfg, lowering=lw)
    logits = L.shard_act(logits, "btv")
    return L.cross_entropy(logits, batch["labels"])


def prefill(params, batch, cfg: ModelConfig, pad_to=None,
            lowering: Optional[LoweringConfig] = None):
    lw = lowering or default_lowering()
    x = L.embed(params["embed"], batch["tokens"], cfg)

    def body(h, bp):
        h2, cache = ssm_block(bp, h, cfg, collect_cache=True, lowering=lw)
        return h2, cache

    h, caches = jax.lax.scan(body, x, params["blocks"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps, lowering=lw)
    logits = L.unembed(params["embed"]["table"], h[:, -1:, :], cfg,
                       lowering=lw)
    return logits[:, 0], caches


def decode_step(params, token, caches, pos, cfg: ModelConfig,
                lowering: Optional[LoweringConfig] = None):
    del pos  # SSM decode is position-free (state carries history)
    lw = lowering or default_lowering()
    x = L.embed(params["embed"], token[:, None], cfg)

    def body(h, xs):
        bp, cache = xs
        h2, new_cache = ssm_block_decode(bp, h, cfg, cache, lowering=lw)
        return h2, new_cache

    h, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps, lowering=lw)
    logits = L.unembed(params["embed"]["table"], h, cfg, lowering=lw)
    return logits[:, 0], new_caches
