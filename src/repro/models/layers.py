"""Shared layer substrate: norms, RoPE, GQA attention, SwiGLU MLP, embeddings.

Parameters are plain nested dicts of jnp arrays.  Every init_* function has a
matching *_axes function returning the same pytree structure with logical-axis
tuples; ``sharding/policies.py`` maps logical axes to mesh axes.

Kernel selection is a *compiler decision*: every hot op (attention, RMSNorm,
matmul) consults ``repro.compile`` at jit-trace time — the dispatcher runs
the e-graph ISAX pipeline once per op kind, caches the lowering per
(op, shape, dtype, backend), and the layer executes whichever implementation
was extracted (Pallas ISAX kernel, chunked-XLA, or the jnp reference).  The
backend preference travels in a ``LoweringConfig`` threaded through the
model families and serve engines; functions fall back to the process-default
lowering when none is passed (trainer, dry-run).  The old module-global
``set_attention_impl`` flag survives only as a deprecation shim.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compile import config as _lowering_config
from repro.compile.config import LoweringConfig, default_lowering
from repro.configs.base import ModelConfig


def set_attention_impl(impl: str) -> None:
    """Deprecated shim: swaps the process-default ``LoweringConfig`` backend.

    Use ``repro.compile.LoweringConfig(backend=...)`` (threaded through
    ``get_model``/the serve engines) or
    ``repro.compile.set_default_backend`` instead.
    """
    warnings.warn(
        "set_attention_impl is deprecated; construct a "
        "repro.compile.LoweringConfig(backend=...) or call "
        "repro.compile.set_default_backend", DeprecationWarning,
        stacklevel=2)
    _lowering_config.set_default_backend(impl)


def get_attention_impl() -> str:
    """Deprecated shim: reads the process-default backend."""
    return _lowering_config.get_default_backend()


# ---------------------------------------------------------------------------
# Activation sharding constraints (opt-in, set by the launcher)
#
# GSPMD's propagation through scanned layer bodies can drift to replicated
# batch layouts; explicit with_sharding_constraint at block boundaries pins
# the intended DP×TP activation layout (standard MaxText-style practice).
# ``_ACT_SPECS`` maps layout kinds → PartitionSpec; None disables (CPU tests).
# ---------------------------------------------------------------------------

_ACT_SPECS: Optional[dict] = None


def set_activation_shardings(specs: Optional[dict]) -> None:
    """specs: {'btd': PartitionSpec, 'btv': ..., 'btf': ...} or None."""
    global _ACT_SPECS
    _ACT_SPECS = specs


def shard_act(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if _ACT_SPECS is None or kind not in _ACT_SPECS:
        return x
    spec = _ACT_SPECS[kind]
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm_axes() -> dict:
    return {"scale": ("embed",)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6, *,
            lowering: Optional[LoweringConfig] = None) -> jnp.ndarray:
    lw = lowering or default_lowering()
    d = x.shape[-1]
    rows = math.prod(x.shape[:-1])
    rec = lw.lower("rmsnorm", (rows, d), x.dtype)
    if rec.impl == "isax":
        out = rec.kernel_fn(x.reshape(rows, d),
                            params["scale"].astype(jnp.float32), eps=eps,
                            interpret=lw.interpret)
        return out.reshape(x.shape).astype(x.dtype)
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                     # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..,S,hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key) -> dict:
    d, H, K = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim()
    dt = dtype_of(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (d, H, hd)) * scale).astype(dt),
        "wk": (jax.random.normal(k2, (d, K, hd)) * scale).astype(dt),
        "wv": (jax.random.normal(k3, (d, K, hd)) * scale).astype(dt),
        "wo": (jax.random.normal(k4, (H, hd, d)) * (H * hd) ** -0.5).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype=dt)
        p["bk"] = jnp.zeros((K, hd), dtype=dt)
        p["bv"] = jnp.zeros((K, hd), dtype=dt)
    return p


def attention_axes(cfg: ModelConfig) -> dict:
    p = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = ("heads", "head_dim")
        p["bk"] = ("kv_heads", "head_dim")
        p["bv"] = ("kv_heads", "head_dim")
    return p


def _qkv(params, x, cfg: ModelConfig, positions):
    cd = dtype_of(cfg.compute_dtype)
    x = x.astype(cd)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(cd)
        k = k + params["bk"].astype(cd)
        v = v + params["bv"].astype(cd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_xla(q, k, v, mask, head_dim: int):
    """Reference scaled-dot-product attention with GQA head grouping.

    q: (B,S,H,hd), k/v: (B,T,K,hd), mask: (1|B, S, T) boolean (True=attend).
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores * (head_dim ** -0.5)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, hd)


def _sdpa_chunked(q, k, v, mask, head_dim: int, chunk: int = 1024):
    """Online-softmax (flash) attention in pure JAX: scans KV in chunks with
    running max/denominator, never materializing the (S, T) score matrix —
    the XLA-path equivalent of the Pallas flash kernel, used by the dry-run
    and valid on TPU.  Chunk size mirrors kernel_synth's block choice."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if T % min(chunk, T):
        return _sdpa_xla(q, k, v, mask, head_dim)
    c = min(chunk, T)
    nk = T // c
    scale = head_dim ** -0.5
    qg = q.reshape(B, S, K, G, hd)
    mask_b = jnp.broadcast_to(mask, (mask.shape[0], S, T))
    k_c = jnp.moveaxis(k.reshape(B, nk, c, K, hd), 1, 0)
    v_c = jnp.moveaxis(v.reshape(B, nk, c, K, hd), 1, 0)
    m_c = jnp.moveaxis(mask_b.reshape(mask_b.shape[0], S, nk, c), 2, 0)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, mc = inp
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kc).astype(jnp.float32)
        s = s * scale
        s = jnp.where(mc[:, None, None, :, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mc[:, None, None, :, :], p, 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bskgd", p.astype(q.dtype),
                        vc).astype(jnp.float32)
        acc_new = acc * jnp.moveaxis(alpha, 3, 1)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    a0 = jnp.zeros((B, S, K, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (k_c, v_c, m_c))
    denom = jnp.maximum(jnp.moveaxis(l, 3, 1), 1e-30)[..., None]
    return (acc / denom).astype(q.dtype).reshape(B, S, H, hd)


def sdpa(q, k, v, mask, head_dim: int, lowering: LoweringConfig,
         kind: str = "attention"):
    """Dispatch-routed scaled-dot-product attention (public: the enc-dec
    family calls it for cross attention).

    The compile cache decides the implementation per (kind, shape, dtype,
    backend); the ISAX kernel entry point is pre-resolved in the record (no
    per-forward import).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    rec = lowering.lower(kind, (B, S, H, K, T, hd), q.dtype)
    if rec.impl == "isax":
        return rec.kernel_fn(q, k, v, mask, sm_scale=head_dim ** -0.5,
                             interpret=lowering.interpret)
    if rec.impl == "chunked":
        return _sdpa_chunked(q, k, v, mask, head_dim)
    return _sdpa_xla(q, k, v, mask, head_dim)


_sdpa = sdpa  # back-compat alias (one release): use layers.sdpa


def attention(params, x, cfg: ModelConfig, mask, positions,
              lowering: Optional[LoweringConfig] = None):
    """Full-sequence attention (train/prefill).  Returns (out, (k, v))."""
    lw = lowering or default_lowering()
    hd = cfg.resolved_head_dim()
    q, k, v = _qkv(params, x, cfg, positions)
    out = sdpa(q, k, v, mask, hd, lw, kind="attention")
    cd = dtype_of(cfg.compute_dtype)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cd)), (k, v)


def attention_decode(params, x, cfg: ModelConfig, k_cache, v_cache, pos,
                     lowering: Optional[LoweringConfig] = None):
    """One-token decode against a static-size KV cache.

    x: (B,1,d); k_cache/v_cache: (B,T,K,hd); pos: () int32 current position.
    Returns (out, new_k_cache, new_v_cache).
    """
    lw = lowering or default_lowering()
    hd = cfg.resolved_head_dim()
    positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
    q, k, v = _qkv(params, x, cfg, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, axis=1)
    T = k_cache.shape[1]
    mask = (jnp.arange(T)[None, None, :] <= pos)  # (1,1,T)
    out = sdpa(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                jnp.broadcast_to(mask, (x.shape[0], 1, T)), hd, lw,
                kind="attention_decode")
    cd = dtype_of(cfg.compute_dtype)
    return (jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cd)),
            k_cache, v_cache)


def attention_decode_paged(params, x, cfg: ModelConfig, k_pages, v_pages,
                           page_table, seq_lens, active,
                           lowering: Optional[LoweringConfig] = None):
    """One-token decode against a block-paged KV pool (vLLM-style).

    x: (B,1,d) new-token activations for every batch slot (inactive slots
    carry dummy tokens so the batch shape is jit-stable).
    k_pages/v_pages: (N, page, K, hd) shared page pools for this layer.
    page_table: (B, P) int32 — logical page p of slot b lives in physical
    page ``page_table[b, p]``; unused entries may hold any valid index
    (their positions are masked).
    seq_lens: (B,) int32 tokens already stored per slot; the new token is
    written at logical position ``seq_lens[b]``.
    active: (B,) bool — inactive slots write nowhere (OOB index + drop).
    Returns (out (B,1,d), k_pages, v_pages).
    """
    lw = lowering or default_lowering()
    hd = cfg.resolved_head_dim()
    B = x.shape[0]
    N, page = k_pages.shape[0], k_pages.shape[1]
    P = page_table.shape[1]
    positions = seq_lens[:, None].astype(jnp.int32)          # (B,1) per-slot
    q, k, v = _qkv(params, x, cfg, positions)
    phys = page_table[jnp.arange(B), seq_lens // page]       # (B,)
    slot = seq_lens % page
    phys = jnp.where(active, phys, N)                        # OOB → dropped
    k_pages = k_pages.at[phys, slot].set(k[:, 0].astype(k_pages.dtype),
                                         mode="drop")
    v_pages = v_pages.at[phys, slot].set(v[:, 0].astype(v_pages.dtype),
                                         mode="drop")
    kg = k_pages[page_table].reshape(B, P * page, *k_pages.shape[2:])
    vg = v_pages[page_table].reshape(B, P * page, *v_pages.shape[2:])
    mask = jnp.arange(P * page)[None, None, :] <= seq_lens[:, None, None]
    out = sdpa(q, kg.astype(q.dtype), vg.astype(q.dtype), mask, hd, lw,
                kind="attention_paged")
    cd = dtype_of(cfg.compute_dtype)
    return (jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cd)),
            k_pages, v_pages)


def make_mask(kind: str, S: int, T: Optional[int] = None,
              n_prefix: int = 0) -> jnp.ndarray:
    """(1, S, T) boolean attention mask."""
    T = T or S
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(T)[None, :]
    if kind == "causal":
        m = cols <= rows
    elif kind == "prefix":  # bidirectional over the first n_prefix tokens
        m = (cols <= rows) | (cols < n_prefix)
    elif kind == "full":
        m = jnp.ones((S, T), dtype=bool)
    else:
        raise ValueError(kind)
    return m[None]


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": (jax.random.normal(k1, (d, ff)) * d ** -0.5).astype(dt),
        "wi_up": (jax.random.normal(k2, (d, ff)) * d ** -0.5).astype(dt),
        "wo": (jax.random.normal(k3, (ff, d)) * ff ** -0.5).astype(dt),
    }


def mlp_axes() -> dict:
    return {"wi_gate": ("embed", "ff"), "wi_up": ("embed", "ff"),
            "wo": ("ff", "embed")}


def mlp(params, x, cfg: ModelConfig,
        lowering: Optional[LoweringConfig] = None):
    lw = lowering or default_lowering()
    cd = dtype_of(cfg.compute_dtype)
    x = x.astype(cd)
    d, ff = params["wi_gate"].shape
    # The bf16/fp32 GEMM is captured through the dispatcher like every other
    # hot op; the ISAX library has no plain-matmul datapath, so the compiler
    # always extracts the XLA reference here (a recorded negative control).
    lw.lower("matmul", (math.prod(x.shape[:-1]), d, ff), x.dtype)
    g = jnp.einsum("bsd,df->bsf", x, params["wi_gate"].astype(cd))
    u = jnp.einsum("bsd,df->bsf", x, params["wi_up"].astype(cd))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                      params["wo"].astype(cd))


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def init_embedding(cfg: ModelConfig, key) -> dict:
    dt = dtype_of(cfg.param_dtype)
    p = {"table": (jax.random.normal(key, (cfg.vocab, cfg.d_model))
                   * cfg.d_model ** -0.5).astype(dt)}
    return p


def embedding_axes() -> dict:
    return {"table": ("vocab", "embed")}


def embed(params, tokens, cfg: ModelConfig):
    cd = dtype_of(cfg.compute_dtype)
    return params["table"].astype(cd)[tokens]


def unembed(table_or_w, x, cfg: ModelConfig,
            lowering: Optional[LoweringConfig] = None):
    lw = lowering or default_lowering()
    cd = dtype_of(cfg.compute_dtype)
    lw.lower("matmul", (math.prod(x.shape[:-1]), x.shape[-1],
                        table_or_w.shape[0]), x.dtype)
    logits = jnp.einsum("bsd,vd->bsv", x.astype(cd),
                        table_or_w.astype(cd))
    return logits.astype(dtype_of(cfg.logit_dtype))


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  ignore_id: int = -1) -> jnp.ndarray:
    """Mean token cross-entropy in fp32; labels == ignore_id are masked."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    w = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)
