"""Mixture-of-Experts layer: top-k token-choice routing with sort-based
capacity dispatch (GShard/Switch style, static shapes — no dynamic slicing,
compile-friendly at 128 experts).

Supports the two assigned MoE archs:
  * arctic-480b — 128 experts, top-2, plus a parallel dense residual MLP
  * dbrx-132b   — 16 experts, top-4

Expert parallelism: the 'experts' logical axis maps to the 'model' mesh axis;
dispatch/combine become all-to-alls under pjit (inserted by GSPMD from the
scatter/gather ops when tokens are data-sharded and experts model-sharded).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compile.config import LoweringConfig, default_lowering
from repro.configs.base import ModelConfig
from repro.models import layers as L

from typing import Optional


def init_moe(cfg: ModelConfig, key) -> dict:
    m = cfg.moe
    d, ff, E = cfg.d_model, cfg.d_ff, m.n_experts
    dt = L.dtype_of(cfg.param_dtype)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(k1, (d, E)) * d ** -0.5
                   ).astype(jnp.float32),
        "wi_gate": (jax.random.normal(k2, (E, d, ff)) * d ** -0.5).astype(dt),
        "wi_up": (jax.random.normal(k3, (E, d, ff)) * d ** -0.5).astype(dt),
        "wo": (jax.random.normal(k4, (E, ff, d)) * ff ** -0.5).astype(dt),
    }
    if m.dense_residual_ff:
        p["dense"] = L.init_mlp(cfg, k5, d_ff=m.dense_residual_ff)
    return p


def moe_axes(cfg: ModelConfig) -> dict:
    p = {
        "router": ("embed", None),
        "wi_gate": ("experts", "embed", "ff"),
        "wi_up": ("experts", "embed", "ff"),
        "wo": ("experts", "ff", "embed"),
    }
    if cfg.moe.dense_residual_ff:
        # arctic's parallel dense residual is small (d_ff 4864); TP-sharding
        # it costs two full activation all-reduces per layer each way — far
        # more than its weights are worth.  Replicate over 'model', shard
        # over 'data' only (§Perf arctic iteration 5).
        p["dense"] = {"wi_gate": ("embed", None), "wi_up": ("embed", None),
                      "wo": (None, "embed")}
    return p


def moe_mlp_grouped(params: dict, x: jnp.ndarray, cfg: ModelConfig,
                    group_size: int = 512,
                    lowering: Optional[LoweringConfig] = None):
    """GShard-style grouped one-hot dispatch (the shardable formulation).

    Tokens are split into G groups of ``group_size``; each group routes to a
    per-group capacity Cg = ⌈k·Tg/E·cf⌉.  Dispatch/combine are einsums with a
    (G, Tg, E, Cg) one-hot tensor — O(G·Tg²·k·cf) elements, linear in total
    tokens for fixed Tg — so GSPMD shards everything cleanly: groups follow
    the data axis, experts the model axis.  This replaces the sort+scatter
    dispatch whose scatter GSPMD can only implement by replicating the
    (E, C, d) buffer and all-reducing it (the §Perf arctic pathology).
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    cd = L.dtype_of(cfg.compute_dtype)
    Tg = min(group_size, T)
    if T % Tg:
        # odd token counts: sort path (directly — routing back through
        # moe_mlp would recurse forever for grouped-dispatch configs)
        return _moe_mlp_sort(params, x, cfg, lowering=lowering)
    G = T // Tg
    xg = x.reshape(G, Tg, d)

    logits = xg.astype(jnp.float32) @ params["router"]        # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                        # (G,Tg,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=(0, 1))
    ce = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32).mean(axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    if T <= 256:  # small token counts: loss-free (mirrors the sort path)
        capacity = Tg
    else:
        capacity = max(1, int(k * Tg / E * m.capacity_factor))
    # expert GEMMs captured as one dispatch op over the G·E·Cg buffer rows
    lw = lowering or default_lowering()
    lw.lower("matmul", (G * E * capacity, d, cfg.d_ff), x.dtype)
    # slot-major positions within each expert (GShard priority order)
    disp = None
    comb = None
    cum = jnp.zeros((G, 1, E), jnp.float32)
    for s in range(k):
        oh = jax.nn.one_hot(idx[..., s], E, dtype=jnp.float32)  # (G,Tg,E)
        pos = jnp.cumsum(oh, axis=1) - oh + cum                 # rank
        cum = cum + oh.sum(axis=1, keepdims=True)
        pos_t = jnp.sum(pos * oh, axis=-1)                      # (G,Tg)
        keep = (pos_t < capacity).astype(jnp.float32)
        pos_oh = jax.nn.one_hot(pos_t.astype(jnp.int32), capacity,
                                dtype=jnp.float32)              # (G,Tg,Cg)
        d_s = oh[..., :, None] * pos_oh[..., None, :] \
            * keep[..., None, None]                             # (G,Tg,E,Cg)
        c_s = d_s * gate[..., s][..., None, None]
        disp = d_s if disp is None else disp + d_s
        comb = c_s if comb is None else comb + c_s
    disp = L.shard_act(disp.astype(cd), "gtec")
    comb = L.shard_act(comb.astype(cd), "gtec")

    buf = jnp.einsum("gtd,gtec->gecd", xg.astype(cd), disp)
    buf = L.shard_act(buf, "gecd")                              # (G,E,Cg,d)
    g_ = jnp.einsum("gecd,edf->gecf", buf, params["wi_gate"].astype(cd))
    u_ = jnp.einsum("gecd,edf->gecf", buf, params["wi_up"].astype(cd))
    h = jax.nn.silu(g_) * u_
    out = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(cd))
    out = L.shard_act(out, "gecd")
    y = jnp.einsum("gecd,gtec->gtd", out, comb).reshape(B, S, d)

    if "dense" in params:
        y = y + L.mlp(params["dense"], x, cfg, lowering=lowering)
    return y.astype(x.dtype), aux


def moe_mlp(params: dict, x: jnp.ndarray, cfg: ModelConfig,
            lowering: Optional[LoweringConfig] = None):
    """x: (B, S, d) → (y: (B, S, d), aux_loss: scalar)."""
    lowering = lowering or default_lowering()
    if (getattr(cfg.moe, "dispatch", "sort") == "grouped"
            and x.shape[0] * x.shape[1] > 1):
        # grouped dispatch also at decode (T = batch tokens): the sort path's
        # scatter is as unshardable there as in training (§Perf addendum)
        return moe_mlp_grouped(params, x, cfg, lowering=lowering)
    return _moe_mlp_sort(params, x, cfg, lowering=lowering)


def _moe_mlp_sort(params: dict, x: jnp.ndarray, cfg: ModelConfig,
                  lowering: Optional[LoweringConfig] = None):
    """Sort+scatter capacity dispatch (minimal FLOPs; GSPMD-hostile scatter).
    Called directly by ``moe_mlp_grouped``'s odd-token fallback so the two
    dispatch strategies never route back into each other."""
    lowering = lowering or default_lowering()
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    cd = L.dtype_of(cfg.compute_dtype)
    xt = x.reshape(T, d)

    # --- routing (fp32) ---
    logits = xt.astype(jnp.float32) @ params["router"]       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                      # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss.
    me = probs.mean(axis=0)                                   # (E,)
    one_hot_top1 = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=0)
    aux = E * jnp.sum(me * ce)

    # --- sort-based capacity dispatch ---
    # Small token counts (decode steps) get loss-free capacity (= T: no drop
    # is possible); large token counts use the configured capacity factor.
    if T <= 256:
        capacity = T
    else:
        capacity = max(1, int(k * T / E * m.capacity_factor))
    # expert GEMMs captured as one dispatch op over the E·C buffer rows
    lowering.lower("matmul", (E * capacity, d, cfg.d_ff), x.dtype)
    flat_e = idx.reshape(-1)                                  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e, stable=True)                  # (T*k,)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    rank = jnp.arange(T * k, dtype=jnp.int32) - start[sorted_e].astype(jnp.int32)
    keep = (rank < capacity)
    dest_p = jnp.minimum(rank, capacity - 1)

    x_sorted = xt[flat_t[order]].astype(cd) * keep[:, None].astype(cd)
    x_sorted = L.shard_act(x_sorted, "td")
    buf = jnp.zeros((E, capacity, d), dtype=cd)
    buf = buf.at[sorted_e, dest_p].add(x_sorted)
    buf = L.shard_act(buf, "ecd")  # experts→model, capacity→data

    # --- expert SwiGLU (grouped GEMMs over the expert axis) ---
    g = jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"].astype(cd))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(cd))
    out_buf = L.shard_act(out_buf, "ecd")

    # --- combine ---
    y_sorted = out_buf[sorted_e, dest_p] * keep[:, None].astype(cd)
    y_sorted = L.shard_act(y_sorted, "td")
    inv = jnp.argsort(order, stable=True)
    y_tk = y_sorted[inv].reshape(T, k, d)
    y = jnp.einsum("tkd,tk->td", y_tk, gate.astype(cd)).reshape(B, S, d)

    if "dense" in params:  # arctic's parallel dense residual branch
        y = y + L.mlp(params["dense"], x, cfg, lowering=lowering)
    return y.astype(x.dtype), aux
