"""zamba2-style hybrid: Mamba2 backbone + ONE shared attention block applied
every ``cfg.shared_attn_every`` layers (single weight set, re-used at every
site — the zamba2 parameter-sharing scheme [arXiv:2411.15242]).

Layers are iterated with a Python loop (heterogeneous sites make a uniform
scan awkward and the model is small); KV caches exist only at the
``n_sites = ceil(L / every)`` attention sites, which is what makes
``long_500k`` feasible for this family (28.7 GB of KV at 500k context,
sharded over the model axis — vs 2.4 TB if every layer carried KV).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compile.config import LoweringConfig, default_lowering
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M


def n_sites(cfg: ModelConfig) -> int:
    return -(-cfg.n_layers // cfg.shared_attn_every)


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kl, ka, km = jax.random.split(key, 4)
    keys = jax.random.split(kl, cfg.n_layers)
    blocks = jax.vmap(lambda k: M.init_ssm_block(cfg, k))(keys)
    shared = {
        "attn_norm": L.init_rmsnorm(cfg.d_model, L.dtype_of(cfg.param_dtype)),
        "attn": L.init_attention(cfg, ka),
        "mlp_norm": L.init_rmsnorm(cfg.d_model, L.dtype_of(cfg.param_dtype)),
        "mlp": L.init_mlp(cfg, km),
    }
    return {
        "embed": L.init_embedding(cfg, ke),
        "blocks": blocks,
        "shared_attn": shared,
        "final_norm": L.init_rmsnorm(cfg.d_model,
                                     L.dtype_of(cfg.param_dtype)),
    }


def param_axes(cfg: ModelConfig) -> dict:
    stack = jax.tree.map(lambda ax: ("layers",) + ax, M.ssm_block_axes(cfg),
                         is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": L.embedding_axes(),
        "blocks": stack,
        "shared_attn": {
            "attn_norm": L.rmsnorm_axes(),
            "attn": L.attention_axes(cfg),
            "mlp_norm": L.rmsnorm_axes(),
            "mlp": L.mlp_axes(),
        },
        "final_norm": L.rmsnorm_axes(),
    }


def _shared_block(params, x, cfg, mask, positions, lowering):
    sp = params["shared_attn"]
    a, kv = L.attention(sp["attn"], L.rmsnorm(sp["attn_norm"], x,
                                              cfg.norm_eps,
                                              lowering=lowering),
                        cfg, mask, positions, lowering=lowering)
    x = x + a
    x = x + L.mlp(sp["mlp"], L.rmsnorm(sp["mlp_norm"], x, cfg.norm_eps,
                                       lowering=lowering), cfg,
                  lowering=lowering)
    return x, kv


def _sites(cfg: ModelConfig) -> list[tuple[int, int]]:
    """(group_start, group_end) per attention site — the mamba layers that
    follow each shared-attention application."""
    every = cfg.shared_attn_every
    return [(s, min(s + every, cfg.n_layers))
            for s in range(0, cfg.n_layers, every)]


def _forward(params, x, cfg: ModelConfig, mask, positions,
             collect_caches: bool,
             lowering: Optional[LoweringConfig] = None):
    """Attention sites are inlined (7 for the full config); the mamba layers
    between sites run under lax.scan on sliced stacked params — keeps the
    HLO size O(sites), not O(layers), for tractable 256-chip compiles."""
    lw = lowering or default_lowering()
    ssm_cache_parts, kv_caches = [], []
    blocks = params["blocks"]
    for start, end in _sites(cfg):
        x = L.shard_act(x, "btd")
        x, kv = _shared_block(params, x, cfg, mask, positions, lw)
        if collect_caches:
            kv_caches.append(kv)
        group = jax.tree.map(lambda a: a[start:end], blocks)

        def body(h, bp):
            h2, cache = M.ssm_block(bp, L.shard_act(h, "btd"), cfg,
                                    collect_cache=collect_caches,
                                    lowering=lw)
            return h2, cache

        x, caches = jax.lax.scan(body, x, group)
        if collect_caches:
            ssm_cache_parts.append(caches)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps, lowering=lw)
    caches = None
    if collect_caches:
        k_stack = jnp.stack([kv[0] for kv in kv_caches])
        v_stack = jnp.stack([kv[1] for kv in kv_caches])
        ssm = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                           *ssm_cache_parts)
        caches = {"k": k_stack, "v": v_stack, "ssm": ssm}
    return x, caches


def loss(params, batch, cfg: ModelConfig,
         lowering: Optional[LoweringConfig] = None):
    x = L.embed(params["embed"], batch["tokens"], cfg)
    B, S, _ = x.shape
    mask = L.make_mask("causal", S)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def fwd(p, h):
        h2, _ = _forward(p, h, cfg, mask, positions, False,
                         lowering=lowering)
        return h2

    h = L.remat_wrap(fwd, cfg.remat)(params, x)
    logits = L.unembed(params["embed"]["table"], h, cfg, lowering=lowering)
    logits = L.shard_act(logits, "btv")
    return L.cross_entropy(logits, batch["labels"])


def prefill(params, batch, cfg: ModelConfig, pad_to=None,
            lowering: Optional[LoweringConfig] = None):
    x = L.embed(params["embed"], batch["tokens"], cfg)
    B, S, _ = x.shape
    mask = L.make_mask("causal", S)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h, caches = _forward(params, x, cfg, mask, positions, True,
                         lowering=lowering)
    if pad_to and pad_to > S:
        pad = [(0, 0), (0, 0), (0, pad_to - S), (0, 0), (0, 0)]
        caches["k"] = jnp.pad(caches["k"], pad)
        caches["v"] = jnp.pad(caches["v"], pad)
    logits = L.unembed(params["embed"]["table"], h[:, -1:, :], cfg,
                       lowering=lowering)
    return logits[:, 0], caches


def decode_step(params, token, caches, pos, cfg: ModelConfig,
                lowering: Optional[LoweringConfig] = None):
    lw = lowering or default_lowering()
    x = L.embed(params["embed"], token[:, None], cfg)
    sp = params["shared_attn"]
    new_k, new_v, new_ssm_parts = [], [], []
    for site, (start, end) in enumerate(_sites(cfg)):
        a, k_c, v_c = L.attention_decode(
            sp["attn"], L.rmsnorm(sp["attn_norm"], x, cfg.norm_eps,
                                  lowering=lw),
            cfg, caches["k"][site], caches["v"][site], pos, lowering=lw)
        x = x + a
        x = x + L.mlp(sp["mlp"], L.rmsnorm(sp["mlp_norm"], x,
                                           cfg.norm_eps, lowering=lw), cfg,
                      lowering=lw)
        new_k.append(k_c)
        new_v.append(v_c)
        group = jax.tree.map(lambda a: a[start:end], params["blocks"])
        group_cache = jax.tree.map(lambda a: a[start:end], caches["ssm"])

        def body(h, xs):
            bp, cache = xs
            h2, c2 = M.ssm_block_decode(bp, h, cfg, cache, lowering=lw)
            return h2, c2

        x, new_cache = jax.lax.scan(body, x, (group, group_cache))
        new_ssm_parts.append(new_cache)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps, lowering=lw)
    logits = L.unembed(params["embed"]["table"], x, cfg, lowering=lw)
    new_caches = {
        "k": jnp.stack(new_k),
        "v": jnp.stack(new_v),
        "ssm": jax.tree.map(lambda *xs: jnp.concatenate(xs),
                            *new_ssm_parts),
    }
    return logits[:, 0], new_caches
