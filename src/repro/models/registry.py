"""Uniform model API over all architecture families + input_specs.

``get_model(cfg)`` returns a ``Model`` with ``init/loss/prefill/decode_step/
param_axes``; ``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins
(weak-type-correct, shardable, zero allocation) for every model input of the
given shape cell — the dry-run contract.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.compile.config import LoweringConfig
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, mamba2, transformer
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    loss: Callable                 # (params, batch) -> scalar
    prefill: Callable              # (params, batch, pad_to) -> (logits, caches)
    decode_step: Callable          # (params, token, caches, pos) -> (logits, caches)
    param_axes: Callable
    # Paged-KV serving entry points (continuous batching); only attention
    # families implement them — None elsewhere.
    prefill_at: Optional[Callable] = None      # (params, batch, length) -> (logits, caches)
    decode_paged: Optional[Callable] = None    # (params, tokens, k_pages, v_pages,
    #                                             page_table, seq_lens, active)
    #                                           -> (logits, k_pages, v_pages)


def get_model(cfg: ModelConfig,
              lowering: Optional[LoweringConfig] = None) -> Model:
    """Bind a family module to a config (and optionally a lowering policy).

    ``lowering`` is threaded into every forward entry point so kernel choice
    is a compile/dispatch decision, not a model-code decision; ``None`` means
    "resolve the process default at trace time" (the trainer/dry-run path).
    """
    if cfg.family in ("dense", "moe", "vlm"):
        mod = transformer
    elif cfg.family == "ssm":
        mod = mamba2
    elif cfg.family == "hybrid":
        mod = hybrid
    elif cfg.family == "encdec":
        mod = encdec
    else:
        raise ValueError(cfg.family)
    paged = {}
    if mod is transformer and cfg.family in ("dense", "moe"):
        paged = {
            "prefill_at": lambda p, b, length: transformer.prefill_at(
                p, b, length, cfg, lowering=lowering),
            "decode_paged": lambda p, t, kp, vp, pt, sl, act:
                transformer.decode_step_paged(p, t, kp, vp, pt, sl, act, cfg,
                                              lowering=lowering),
        }
    return Model(
        cfg=cfg,
        init=lambda key: mod.init_params(cfg, key),
        loss=lambda p, b: mod.loss(p, b, cfg, lowering=lowering),
        prefill=lambda p, b, pad_to=None: mod.prefill(p, b, cfg,
                                                      pad_to=pad_to,
                                                      lowering=lowering),
        decode_step=lambda p, t, c, pos: mod.decode_step(p, t, c, pos, cfg,
                                                         lowering=lowering),
        param_axes=lambda: mod.param_axes(cfg),
        **paged,
    )


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                with_labels: bool) -> dict:
    """Specs for a train/prefill batch of this shape cell."""
    B, S = shape.global_batch, shape.seq_len
    cd = L.dtype_of(cfg.compute_dtype)
    out = {}
    if cfg.family == "vlm":
        P = cfg.n_prefix_tokens
        out["prefix_embeds"] = _sds((B, P, cfg.d_model), cd)
        out["tokens"] = _sds((B, S - P), jnp.int32)
        if with_labels:
            out["labels"] = _sds((B, S - P), jnp.int32)
    elif cfg.family == "encdec":
        out["prefix_embeds"] = _sds((B, cfg.n_prefix_tokens, cfg.d_model), cd)
        out["tokens"] = _sds((B, S), jnp.int32)
        if with_labels:
            out["labels"] = _sds((B, S), jnp.int32)
    else:
        out["tokens"] = _sds((B, S), jnp.int32)
        if with_labels:
            out["labels"] = _sds((B, S), jnp.int32)
    return out


def cache_specs(cfg: ModelConfig, batch: int, T: int) -> dict:
    """Decode-time cache specs (the serve_step state for one new token)."""
    cd = L.dtype_of(cfg.compute_dtype)
    Lk = cfg.n_layers
    hd = cfg.resolved_head_dim() if cfg.n_heads else 0
    K = cfg.n_kv_heads
    if cfg.family in ("dense", "moe", "vlm"):
        return {"k": _sds((Lk, batch, T, K, hd), cd),
                "v": _sds((Lk, batch, T, K, hd), cd)}
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        H = d_in // s.head_dim
        ch = d_in + 2 * s.d_state
        return {"conv": _sds((Lk, batch, s.conv_width - 1, ch), cd),
                "state": _sds((Lk, batch, H, s.d_state, s.head_dim),
                              jnp.float32)}
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        H = d_in // s.head_dim
        ch = d_in + 2 * s.d_state
        sites = hybrid.n_sites(cfg)
        return {
            "k": _sds((sites, batch, T, K, hd), cd),
            "v": _sds((sites, batch, T, K, hd), cd),
            "ssm": {"conv": _sds((Lk, batch, s.conv_width - 1, ch), cd),
                    "state": _sds((Lk, batch, H, s.d_state, s.head_dim),
                                  jnp.float32)},
        }
    if cfg.family == "encdec":
        return {"k": _sds((Lk, batch, T, K, hd), cd),
                "v": _sds((Lk, batch, T, K, hd), cd),
                "enc_out": _sds((batch, cfg.n_prefix_tokens, cfg.d_model),
                                cd)}
    raise ValueError(cfg.family)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """All inputs for the shape cell's step function.

    train  → {'batch': …}                              (for train_step)
    prefill→ {'batch': …}                              (for prefill)
    decode → {'token', 'caches', 'pos'}                (for serve_step)
    """
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape, with_labels=False)}
    if shape.kind == "decode":
        B = shape.global_batch
        return {
            "token": _sds((B,), jnp.int32),
            "caches": cache_specs(cfg, B, shape.seq_len),
            "pos": _sds((), jnp.int32),
        }
    raise ValueError(shape.kind)


def param_specs(cfg: ModelConfig) -> dict:
    """ShapeDtypeStructs of the parameter tree (eval_shape — no allocation)."""
    model = get_model(cfg)
    return jax.eval_shape(model.init, jax.random.key(0))
