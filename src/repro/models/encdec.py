"""Encoder–decoder transformer (seamless-m4t-medium backbone).

12 encoder + 12 decoder layers.  The audio frontend is a stub per the
assignment: the encoder consumes precomputed frame embeddings
(batch, n_frames, d_model) from ``input_specs``.  The decoder is a standard
causal stack with cross-attention; decode shapes exercise ``decode_step``
with a self-attention KV cache plus the (fixed) encoder output.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compile.config import LoweringConfig, default_lowering
from repro.configs.base import ModelConfig
from repro.models import layers as L


def _cross_attention(params, x, enc_out, cfg: ModelConfig, mask, lowering):
    """Cross-attn: queries from x, keys/values from encoder output."""
    cd = L.dtype_of(cfg.compute_dtype)
    hd = cfg.resolved_head_dim()
    x = x.astype(cd)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cd))
    k = jnp.einsum("btd,dhk->bthk", enc_out.astype(cd),
                   params["wk"].astype(cd))
    v = jnp.einsum("btd,dhk->bthk", enc_out.astype(cd),
                   params["wv"].astype(cd))
    out = L.sdpa(q, k, v, mask, hd, lowering, kind="attention")
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cd))


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kenc, kdec, ku = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.n_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn_norm": L.init_rmsnorm(cfg.d_model,
                                        L.dtype_of(cfg.param_dtype)),
            "attn": L.init_attention(cfg, k1),
            "mlp_norm": L.init_rmsnorm(cfg.d_model,
                                       L.dtype_of(cfg.param_dtype)),
            "mlp": L.init_mlp(cfg, k2),
        }

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "attn_norm": L.init_rmsnorm(cfg.d_model,
                                        L.dtype_of(cfg.param_dtype)),
            "attn": L.init_attention(cfg, k1),
            "cross_norm": L.init_rmsnorm(cfg.d_model,
                                         L.dtype_of(cfg.param_dtype)),
            "cross": L.init_attention(cfg, k2),
            "mlp_norm": L.init_rmsnorm(cfg.d_model,
                                       L.dtype_of(cfg.param_dtype)),
            "mlp": L.init_mlp(cfg, k3),
        }

    return {
        "embed": L.init_embedding(cfg, ke),
        "enc_blocks": jax.vmap(enc_block)(enc_keys),
        "enc_norm": L.init_rmsnorm(cfg.d_model, L.dtype_of(cfg.param_dtype)),
        "dec_blocks": jax.vmap(dec_block)(dec_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model,
                                     L.dtype_of(cfg.param_dtype)),
        "unembed": {"w": (jax.random.normal(ku, (cfg.vocab, cfg.d_model))
                          * cfg.d_model ** -0.5
                          ).astype(L.dtype_of(cfg.param_dtype))},
    }


def param_axes(cfg: ModelConfig) -> dict:
    def stacked(d):
        return jax.tree.map(lambda ax: ("layers",) + ax, d,
                            is_leaf=lambda x: isinstance(x, tuple))

    enc = {"attn_norm": L.rmsnorm_axes(), "attn": L.attention_axes(cfg),
           "mlp_norm": L.rmsnorm_axes(), "mlp": L.mlp_axes()}
    dec = {"attn_norm": L.rmsnorm_axes(), "attn": L.attention_axes(cfg),
           "cross_norm": L.rmsnorm_axes(), "cross": L.attention_axes(cfg),
           "mlp_norm": L.rmsnorm_axes(), "mlp": L.mlp_axes()}
    return {
        "embed": L.embedding_axes(),
        "enc_blocks": stacked(enc),
        "enc_norm": L.rmsnorm_axes(),
        "dec_blocks": stacked(dec),
        "final_norm": L.rmsnorm_axes(),
        "unembed": {"w": ("vocab", "embed")},
    }


def encode(params, frame_embeds, cfg: ModelConfig,
           lowering: Optional[LoweringConfig] = None):
    lw = lowering or default_lowering()
    B, T, _ = frame_embeds.shape
    mask = L.make_mask("full", T)
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    x = frame_embeds.astype(L.dtype_of(cfg.compute_dtype))

    def body(h, bp):
        h = L.shard_act(h, "btd")
        a, _ = L.attention(bp["attn"],
                           L.rmsnorm(bp["attn_norm"], h, cfg.norm_eps,
                                     lowering=lw),
                           cfg, mask, positions, lowering=lw)
        h = h + a
        h = h + L.mlp(bp["mlp"], L.rmsnorm(bp["mlp_norm"], h, cfg.norm_eps,
                                           lowering=lw), cfg, lowering=lw)
        return h, None

    body = L.remat_wrap(body, cfg.remat)
    h, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rmsnorm(params["enc_norm"], h, cfg.norm_eps, lowering=lw)


def _decoder(params, x, enc_out, cfg, self_mask, cross_mask, positions,
             collect_kv=False, lowering=None):
    lw = lowering or default_lowering()

    def body(h, bp):
        h = L.shard_act(h, "btd")
        a, kv = L.attention(bp["attn"],
                            L.rmsnorm(bp["attn_norm"], h, cfg.norm_eps,
                                      lowering=lw),
                            cfg, self_mask, positions, lowering=lw)
        h = h + a
        h = h + _cross_attention(bp["cross"],
                                 L.rmsnorm(bp["cross_norm"], h, cfg.norm_eps,
                                           lowering=lw),
                                 enc_out, cfg, cross_mask, lw)
        h = h + L.mlp(bp["mlp"], L.rmsnorm(bp["mlp_norm"], h, cfg.norm_eps,
                                           lowering=lw), cfg, lowering=lw)
        return h, kv if collect_kv else None

    body = L.remat_wrap(body, cfg.remat)
    h, kv = jax.lax.scan(body, x, params["dec_blocks"])
    return L.rmsnorm(params["final_norm"], h, cfg.norm_eps, lowering=lw), kv


def loss(params, batch, cfg: ModelConfig,
         lowering: Optional[LoweringConfig] = None):
    """batch: prefix_embeds (B,T,d) [audio frames], tokens (B,S), labels."""
    enc_out = encode(params, batch["prefix_embeds"], cfg, lowering=lowering)
    x = L.embed(params["embed"], batch["tokens"], cfg)
    B, S, _ = x.shape
    T = enc_out.shape[1]
    self_mask = L.make_mask("causal", S)
    cross_mask = L.make_mask("full", S, T)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h, _ = _decoder(params, x, enc_out, cfg, self_mask, cross_mask, positions,
                    lowering=lowering)
    logits = L.unembed(params["unembed"]["w"], h, cfg, lowering=lowering)
    logits = L.shard_act(logits, "btv")
    return L.cross_entropy(logits, batch["labels"])


def prefill(params, batch, cfg: ModelConfig, pad_to=None,
            lowering: Optional[LoweringConfig] = None):
    enc_out = encode(params, batch["prefix_embeds"], cfg, lowering=lowering)
    x = L.embed(params["embed"], batch["tokens"], cfg)
    B, S, _ = x.shape
    T = enc_out.shape[1]
    self_mask = L.make_mask("causal", S)
    cross_mask = L.make_mask("full", S, T)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h, kv = _decoder(params, x, enc_out, cfg, self_mask, cross_mask,
                     positions, collect_kv=True, lowering=lowering)
    k_stack, v_stack = kv
    if pad_to and pad_to > S:
        pad = [(0, 0), (0, 0), (0, pad_to - S), (0, 0), (0, 0)]
        k_stack = jnp.pad(k_stack, pad)
        v_stack = jnp.pad(v_stack, pad)
    logits = L.unembed(params["unembed"]["w"], h[:, -1:, :], cfg,
                       lowering=lowering)
    return logits[:, 0], {"k": k_stack, "v": v_stack, "enc_out": enc_out}


def decode_step(params, token, caches, pos, cfg: ModelConfig,
                lowering: Optional[LoweringConfig] = None):
    lw = lowering or default_lowering()
    x = L.embed(params["embed"], token[:, None], cfg)
    enc_out = caches["enc_out"]
    B = x.shape[0]
    T = enc_out.shape[1]
    cross_mask = jnp.ones((B, 1, T), bool)

    def body(h, xs):
        bp, k_c, v_c = xs
        a, k_c, v_c = L.attention_decode(
            bp["attn"], L.rmsnorm(bp["attn_norm"], h, cfg.norm_eps,
                                  lowering=lw),
            cfg, k_c, v_c, pos, lowering=lw)
        h = h + a
        h = h + _cross_attention(bp["cross"],
                                 L.rmsnorm(bp["cross_norm"], h, cfg.norm_eps,
                                           lowering=lw),
                                 enc_out, cfg, cross_mask, lw)
        h = h + L.mlp(bp["mlp"], L.rmsnorm(bp["mlp_norm"], h, cfg.norm_eps,
                                           lowering=lw), cfg, lowering=lw)
        return h, (k_c, v_c)

    h, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_blocks"], caches["k"], caches["v"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps, lowering=lw)
    logits = L.unembed(params["unembed"]["w"], h, cfg, lowering=lw)
    return logits[:, 0], {"k": k_new, "v": v_new, "enc_out": enc_out}
