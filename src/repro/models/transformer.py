"""Decoder-only transformer covering the dense, moe, and vlm families.

Layers are scan-stacked (params carry a leading 'layers' axis) so HLO size
and compile time are depth-independent — required for 1000+ chip compiles.

Entry points:
  init_params / param_axes
  loss(params, batch)                    — train_4k
  prefill(params, batch)                 — prefill_32k (returns last logits + caches)
  decode_step(params, token, caches, pos)— decode_32k / serving
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compile.config import LoweringConfig, default_lowering
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.moe import init_moe, moe_axes, moe_mlp


def _is_moe(cfg: ModelConfig) -> bool:
    return cfg.moe is not None


def init_block(cfg: ModelConfig, key) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": L.init_rmsnorm(cfg.d_model, L.dtype_of(cfg.param_dtype)),
        "attn": L.init_attention(cfg, k1),
        "mlp_norm": L.init_rmsnorm(cfg.d_model, L.dtype_of(cfg.param_dtype)),
    }
    p["moe" if _is_moe(cfg) else "mlp"] = (
        init_moe(cfg, k2) if _is_moe(cfg) else L.init_mlp(cfg, k2))
    return p


def block_axes(cfg: ModelConfig) -> dict:
    p = {
        "attn_norm": L.rmsnorm_axes(),
        "attn": L.attention_axes(cfg),
        "mlp_norm": L.rmsnorm_axes(),
    }
    if _is_moe(cfg):
        p["moe"] = moe_axes(cfg)
    else:
        p["mlp"] = L.mlp_axes()
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kl, ku = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    blocks = jax.vmap(lambda k: init_block(cfg, k))(layer_keys)
    p = {
        "embed": L.init_embedding(cfg, ke),
        "blocks": blocks,
        "final_norm": L.init_rmsnorm(cfg.d_model, L.dtype_of(cfg.param_dtype)),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"w": (jax.random.normal(ku, (cfg.vocab, cfg.d_model))
                              * cfg.d_model ** -0.5
                              ).astype(L.dtype_of(cfg.param_dtype))}
    return p


def param_axes(cfg: ModelConfig) -> dict:
    stack = jax.tree.map(lambda ax: ("layers",) + ax, block_axes(cfg),
                         is_leaf=lambda x: isinstance(x, tuple))
    p = {
        "embed": L.embedding_axes(),
        "blocks": stack,
        "final_norm": L.rmsnorm_axes(),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"w": ("vocab", "embed")}
    return p


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def block_fwd(cfg: ModelConfig, x, bp, mask, positions, lowering=None):
    x = L.shard_act(x, "btd")
    a, kv = L.attention(bp["attn"],
                        L.rmsnorm(bp["attn_norm"], x, cfg.norm_eps,
                                  lowering=lowering),
                        cfg, mask, positions, lowering=lowering)
    x = x + a
    if _is_moe(cfg):
        y, aux = moe_mlp(bp["moe"],
                         L.rmsnorm(bp["mlp_norm"], x, cfg.norm_eps,
                                   lowering=lowering),
                         cfg, lowering=lowering)
    else:
        y = L.mlp(bp["mlp"], L.rmsnorm(bp["mlp_norm"], x, cfg.norm_eps,
                                       lowering=lowering), cfg,
                  lowering=lowering)
        aux = jnp.zeros((), jnp.float32)
    return x + y, aux, kv


_block_fwd = block_fwd  # back-compat alias (one release): use block_fwd


def backbone(params, x, cfg: ModelConfig, mask, positions,
             collect_kv: bool = False,
             lowering: Optional[LoweringConfig] = None):
    """Scan over stacked blocks.  Returns (hidden, aux, kv_stack|None)."""
    lw = lowering or default_lowering()

    def body(carry, bp):
        h, aux = carry
        h2, a, kv = block_fwd(cfg, h, bp, mask, positions, lw)
        ys = kv if collect_kv else None
        return (h2, aux + a), ys

    body = L.remat_wrap(body, cfg.remat)
    (h, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                params["blocks"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps, lowering=lw)
    return h, aux, ys


def _unembed_table(params, cfg: ModelConfig):
    return (params["embed"]["table"] if cfg.tie_embeddings
            else params["unembed"]["w"])


def _inputs_to_x(params, batch, cfg: ModelConfig):
    """tokens (+ optional prefix_embeds for vlm/stub frontends) → (x, S)."""
    x = L.embed(params["embed"], batch["tokens"], cfg)
    if cfg.n_prefix_tokens and "prefix_embeds" in batch:
        pe = batch["prefix_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    return x


def loss(params, batch, cfg: ModelConfig, aux_weight: float = 0.01,
         lowering: Optional[LoweringConfig] = None):
    """batch: tokens (B, S_text), labels (B, S_text) [, prefix_embeds]."""
    x = _inputs_to_x(params, batch, cfg)
    B, S, _ = x.shape
    mask_kind = "prefix" if cfg.family == "vlm" else "causal"
    mask = L.make_mask(mask_kind, S, n_prefix=cfg.n_prefix_tokens
                       if cfg.family == "vlm" else 0)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h, aux, _ = backbone(params, x, cfg, mask, positions, lowering=lowering)
    logits = L.unembed(_unembed_table(params, cfg), h, cfg,
                       lowering=lowering)
    logits = L.shard_act(logits, "btv")
    n_pref = x.shape[1] - batch["tokens"].shape[1]
    logits = logits[:, n_pref:, :]
    return L.cross_entropy(logits, batch["labels"]) + aux_weight * aux


def prefill(params, batch, cfg: ModelConfig, pad_to: Optional[int] = None,
            lowering: Optional[LoweringConfig] = None):
    """Returns (last-position logits, kv caches stacked over layers, length)."""
    x = _inputs_to_x(params, batch, cfg)
    B, S, _ = x.shape
    mask_kind = "prefix" if cfg.family == "vlm" else "causal"
    mask = L.make_mask(mask_kind, S, n_prefix=cfg.n_prefix_tokens
                       if cfg.family == "vlm" else 0)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h, _, kv = backbone(params, x, cfg, mask, positions, collect_kv=True,
                        lowering=lowering)
    k_stack, v_stack = kv  # (L, B, S, K, hd)
    if pad_to and pad_to > S:
        pad = [(0, 0), (0, 0), (0, pad_to - S), (0, 0), (0, 0)]
        k_stack = jnp.pad(k_stack, pad)
        v_stack = jnp.pad(v_stack, pad)
    logits = L.unembed(_unembed_table(params, cfg), h[:, -1:, :], cfg,
                       lowering=lowering)
    return logits[:, 0], {"k": k_stack, "v": v_stack}


def prefill_at(params, batch, length, cfg: ModelConfig,
               lowering: Optional[LoweringConfig] = None):
    """Prefill a (possibly right-padded) prompt and read logits at position
    ``length - 1`` instead of the last position.  Under a causal mask the
    hidden states and KV at positions < ``length`` are unaffected by padding
    tokens after them, so this is exact for bucketed prompts.

    batch: {'tokens': (B, S_pad)}; length: () int32 true prompt length.
    Returns (logits (B, vocab), {'k','v'} (L, B, S_pad, K, hd)).
    """
    x = _inputs_to_x(params, batch, cfg)
    B, S, _ = x.shape
    mask = L.make_mask("causal", S)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h, _, kv = backbone(params, x, cfg, mask, positions, collect_kv=True,
                        lowering=lowering)
    k_stack, v_stack = kv
    h_last = jax.lax.dynamic_slice_in_dim(h, length - 1, 1, axis=1)
    logits = L.unembed(_unembed_table(params, cfg), h_last, cfg,
                       lowering=lowering)
    return logits[:, 0], {"k": k_stack, "v": v_stack}


def decode_step_paged(params, tokens, k_pages, v_pages, page_table, seq_lens,
                      active, cfg: ModelConfig,
                      lowering: Optional[LoweringConfig] = None):
    """One-token decode through the paged KV pools (see
    ``layers.attention_decode_paged``).  tokens: (B,) int32; pools carry a
    leading layer axis (L, N, page, K, hd) and are scanned alongside the
    stacked block params so the batch/pool shapes stay constant across
    request admissions and evictions.

    Returns (logits (B, vocab), k_pages, v_pages).
    """
    lw = lowering or default_lowering()
    x = L.embed(params["embed"], tokens[:, None], cfg)  # (B,1,d)

    def body(h, xs):
        bp, kp, vp = xs
        a, kp, vp = L.attention_decode_paged(
            bp["attn"], L.rmsnorm(bp["attn_norm"], h, cfg.norm_eps,
                                  lowering=lw),
            cfg, kp, vp, page_table, seq_lens, active, lowering=lw)
        h = h + a
        if _is_moe(cfg):
            y, _ = moe_mlp(bp["moe"], L.rmsnorm(bp["mlp_norm"], h,
                                                cfg.norm_eps, lowering=lw),
                           cfg, lowering=lw)
        else:
            y = L.mlp(bp["mlp"], L.rmsnorm(bp["mlp_norm"], h, cfg.norm_eps,
                                           lowering=lw), cfg, lowering=lw)
        return h + y, (kp, vp)

    h, (k_new, v_new) = jax.lax.scan(
        body, x, (params["blocks"], k_pages, v_pages))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps, lowering=lw)
    logits = L.unembed(_unembed_table(params, cfg), h, cfg, lowering=lw)
    return logits[:, 0], k_new, v_new


def decode_step(params, token, caches, pos, cfg: ModelConfig,
                lowering: Optional[LoweringConfig] = None):
    """One-token decode.  token: (B,) int32; caches: {'k','v'} (L,B,T,K,hd);
    pos: () int32.  Returns (logits (B, vocab), new caches)."""
    lw = lowering or default_lowering()
    x = L.embed(params["embed"], token[:, None], cfg)  # (B,1,d)

    def body(h, xs):
        bp, k_c, v_c = xs
        a, k_c, v_c = L.attention_decode(
            bp["attn"], L.rmsnorm(bp["attn_norm"], h, cfg.norm_eps,
                                  lowering=lw),
            cfg, k_c, v_c, pos, lowering=lw)
        h = h + a
        if _is_moe(cfg):
            y, _ = moe_mlp(bp["moe"], L.rmsnorm(bp["mlp_norm"], h,
                                                cfg.norm_eps, lowering=lw),
                           cfg, lowering=lw)
        else:
            y = L.mlp(bp["mlp"], L.rmsnorm(bp["mlp_norm"], h, cfg.norm_eps,
                                           lowering=lw), cfg, lowering=lw)
        return h + y, (k_c, v_c)

    h, (k_new, v_new) = jax.lax.scan(
        body, x, (params["blocks"], caches["k"], caches["v"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps, lowering=lw)
    logits = L.unembed(_unembed_table(params, cfg), h, cfg, lowering=lw)
    return logits[:, 0], {"k": k_new, "v": v_new}
