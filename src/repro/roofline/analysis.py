"""Three-term roofline from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_wire_bytes_per_chip / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are not
in cost_analysis, so ``parse_collectives`` walks the optimized HLO text and
sums per-op wire bytes with ring-algorithm factors:

    all-reduce      2·S·(G−1)/G        (S = tensor bytes, G = group size)
    all-gather      R·(G−1)/G          (R = result bytes)
    reduce-scatter  R·(G−1)            (result is the scattered shard)
    all-to-all      R·(G−1)/G
    collective-permute  R

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(line: str, opname: str) -> int:
    """Sum the shapes on the lhs (before the op name)."""
    head = line.split(opname, 1)[0]
    return sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(head))


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    result_bytes: dict
    wire_bytes_per_chip: float
    in_loop_counts: dict | None = None

    def total_result_bytes(self) -> float:
        return sum(self.result_bytes.values())


def _computation_header(stripped: str) -> str | None:
    """HLO computation headers look like
    ``[ENTRY ]%name.123 (p: f32[..], ...) -> ret { ``; nested parens in the
    parameter list make a strict regex unreliable — match structurally."""
    if not stripped.endswith("{") or "->" not in stripped:
        return None
    tok = stripped.split()[0]
    if tok == "ENTRY":
        return "ENTRY"
    return tok.lstrip("%")


def parse_collectives(hlo_text: str, n_chips: int,
                      loop_trip: int = 1) -> CollectiveStats:
    """Sum per-op wire bytes.  Ops inside while-loop body computations are
    weighted by ``loop_trip`` (the layer-scan trip count): HLO text lists a
    scan-body collective once, but it executes once per layer."""
    counts = {k: 0 for k in COLLECTIVE_OPS}
    in_loop = {k: 0 for k in COLLECTIVE_OPS}
    rbytes = {k: 0.0 for k in COLLECTIVE_OPS}
    wire = 0.0
    cur_comp = ""
    for line in hlo_text.splitlines():
        stripped = line.strip()
        header = _computation_header(stripped)
        if header is not None:
            cur_comp = header
            continue
        if stripped == "}":
            continue
        for op in COLLECTIVE_OPS:
            token = f" {op}("
            if token not in stripped:
                continue
            r = _result_bytes(stripped, token)
            if r == 0:
                continue
            g = _group_size(stripped, n_chips)
            # JAX scan/while bodies lower to computations named
            # region_N[.M][_spmd][.clone]* (wide.* when batched); reduction
            # regions are also "region" but never contain collectives.
            looped = ("body" in cur_comp or "while" in cur_comp
                      or "scan" in cur_comp or "region" in cur_comp)
            mult = loop_trip if looped else 1
            counts[op] += 1
            in_loop[op] += int(looped)
            rbytes[op] += r * mult
            if op == "all-reduce":
                wire += 2 * r * (g - 1) / max(g, 1) * mult
            elif op == "all-gather":
                wire += r * (g - 1) / max(g, 1) * mult
            elif op == "reduce-scatter":
                wire += r * (g - 1) * mult
            elif op == "all-to-all":
                wire += r * (g - 1) / max(g, 1) * mult
            else:  # collective-permute
                wire += r * mult
            break
    return CollectiveStats(counts, rbytes, wire, in_loop)


def pipeline_speedup(flops: float, hbm_bytes: float,
                     n_chips: int = 1) -> float:
    """Roofline-level speedup bound for overlapping HBM streaming with
    compute (the burst-DMA pipeline of ``kernels/pipeline.py``).

    Serialized execution pays ``compute_s + memory_s``; a perfectly
    overlapped pipeline pays ``max(compute_s, memory_s)``.  The ratio is the
    best case any buffer depth can reach — ``core.kernel_synth`` takes the
    minimum of this bound and its interface-model estimate, so the pipelined
    kernel is never auto-selected on a predicted loss.
    """
    compute_s = flops / (n_chips * PEAK_FLOPS)
    memory_s = hbm_bytes / (n_chips * HBM_BW)
    overlapped = max(compute_s, memory_s)
    if overlapped <= 0:
        return 1.0
    return (compute_s + memory_s) / overlapped


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    n_chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float

    def row(self) -> dict:
        return dataclasses.asdict(self)


def roofline(flops: float, hbm_bytes: float, wire_bytes_per_chip: float,
             n_chips: int, model_flops: float = 0.0) -> Roofline:
    compute_s = flops / (n_chips * PEAK_FLOPS)
    memory_s = hbm_bytes / (n_chips * HBM_BW)
    collective_s = wire_bytes_per_chip / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / flops if flops else 0.0
    return Roofline(flops, hbm_bytes, wire_bytes_per_chip, n_chips,
                    compute_s, memory_s, collective_s, bottleneck,
                    model_flops, useful)
