"""Jit'd public wrappers around the Pallas kernels.

Each wrapper: (1) derives tile shapes from the interface-aware synthesis flow
(``core.kernel_synth``) instead of hand-tuned constants — the paper's C1
applied to kernel configuration; (2) pads/falls back gracefully when a shape
can't be tiled; (3) exposes an ``interpret=`` flag so the CPU container can
execute the kernel bodies for correctness.

Also registers e-graph intrinsics (``core.offload``) backed by the
interpret-mode kernels, so offloaded programs execute through the same
datapaths the "hardware" provides.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernel_synth import (
    choose_flash_blocks,
    choose_matmul_blocks,
    choose_ssd_blocks,
)
from repro.core.tiling import down_pow2
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.int8_matmul import int8_matmul as _int8mm
from repro.kernels.pipeline import (
    flash_attention_pipelined as _flash_pipe,
    int8_matmul_pipelined as _int8mm_pipe,
    ssd_scan_pipelined as _ssd_pipe,
    use_pipeline,
)
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.ssd_scan import ssd_scan as _ssd


@functools.lru_cache(maxsize=None)
def _flash_schedule(S: int, T: int, hd: int, dtype_bytes: int):
    return choose_flash_blocks(S, T, hd, dtype_bytes)


@functools.lru_cache(maxsize=None)
def _matmul_schedule(M: int, N: int, K: int, dtype_bytes: int):
    return choose_matmul_blocks(M, N, K, dtype_bytes=dtype_bytes)


@functools.lru_cache(maxsize=None)
def _ssd_schedule(S: int, H: int, P: int, N: int):
    return choose_ssd_blocks(S, H, P, N)


# Back-compat aliases (one release): the tile/routing helpers are public
# now — ``repro.core.tiling.down_pow2`` and ``kernels.pipeline.use_pipeline``.
_use_pipeline = use_pipeline
_down_pow2 = down_pow2


def flash_attention_gqa(q, k, v, mask, *, sm_scale: float,
                        interpret: bool = False,
                        pipelined: bool | None = None):
    """Drop-in for layers._sdpa: synthesis-chosen tiles, ref fallback for
    shapes the kernel can't tile (tiny smoke shapes).  ``pipelined`` routes
    K/V streaming through the burst-DMA pipeline (None = the synthesized
    cost-model decision)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    sched = _flash_schedule(S, T, hd, q.dtype.itemsize)
    bq = down_pow2(S, sched.block("q")[0])
    bk = down_pow2(T, sched.block("kv")[0])
    if S % bq or T % bk or H % k.shape[2]:
        return ref.flash_attention_ref(q, k, v, mask, sm_scale=sm_scale)
    mask = jnp.broadcast_to(mask, (mask.shape[0], S, T))
    if use_pipeline(sched, pipelined, T // bk):
        return _flash_pipe(q, k, v, mask, sm_scale=sm_scale, block_q=bq,
                           block_k=bk, depth=max(2, sched.buffering),
                           interpret=interpret)
    return _flash(q, k, v, mask, sm_scale=sm_scale, block_q=bq, block_k=bk,
                  interpret=interpret)


def int8_matmul(x, wq, scale, *, interpret: bool = False,
                pipelined: bool | None = None):
    """Quantized GEMM with synthesis-chosen tiles; ``pipelined`` routes the
    int8 weight (and activation) tiles through the burst-DMA pipeline
    (None = the synthesized cost-model decision)."""
    M, K = x.shape
    N = wq.shape[0]
    sched = _matmul_schedule(M, N, K, 1)
    bm = down_pow2(M, sched.block("a")[0])
    bn = down_pow2(N, sched.block("b")[1])
    bk = down_pow2(K, sched.block("a")[1])
    if M % bm or N % bn or K % bk:
        return ref.int8_matmul_ref(x, wq, scale)
    if use_pipeline(sched, pipelined, K // bk):
        return _int8mm_pipe(x, wq, scale, block_m=bm, block_n=bn,
                            block_k=bk, depth=max(2, sched.buffering),
                            interpret=interpret)
    return _int8mm(x, wq, scale, block_m=bm, block_n=bn, block_k=bk,
                   interpret=interpret)


def ssd_scan(x, dt, A, B, C, *, interpret: bool = False,
             pipelined: bool | None = None):
    """SSD chunked scan with synthesis-chosen chunk length; ``pipelined``
    streams the x/B/C chunks through the burst-DMA pipeline (None = the
    synthesized cost-model decision)."""
    BT, H, S, P = x.shape
    N = B.shape[-1]
    sched = _ssd_schedule(S, H, P, N)
    chunk = down_pow2(S, sched.block("chunk")[0])
    if S % chunk:
        return ref.ssd_scan_ref(x, dt, A, B, C)
    if use_pipeline(sched, pipelined, S // chunk):
        return _ssd_pipe(x, dt, A, B, C, chunk=chunk,
                         depth=max(2, sched.buffering), interpret=interpret)
    return _ssd(x, dt, A, B, C, chunk=chunk, interpret=interpret)


def rmsnorm(x, g, *, eps: float = 1e-6, interpret: bool = False):
    """Row-blocked RMSNorm: x (R,d), g (d) → (R,d)."""
    R = x.shape[0]
    br = down_pow2(R, 256)
    return _rmsnorm(x, g, eps=eps, block_rows=br, interpret=interpret)


# ---------------------------------------------------------------------------
# E-graph intrinsic registration: the offloaded "custom instructions" execute
# the fused datapath.  On this CPU host the fused path is the jit'd oracle
# (one fused XLA computation — what the hardware datapath provides); the
# Pallas kernel bodies themselves are validated separately in interpret mode
# (tests/test_kernels.py, REPRO_INTRINSIC_INTERPRET=1 forces them here too).
# ---------------------------------------------------------------------------

import os as _os

_INTERPRET = _os.environ.get("REPRO_INTRINSIC_INTERPRET", "0") == "1"


def _as_f32(a):
    return jnp.asarray(np.asarray(a), jnp.float32)


@functools.lru_cache(maxsize=None)
def _jit_flash():
    def _f(q, k, v, mask, scale):
        return ref.flash_attention_ref(q, k, v, mask, sm_scale=scale)
    return jax.jit(_f, static_argnums=(4,))


@functools.lru_cache(maxsize=None)
def _jit_int8():
    return jax.jit(ref.int8_matmul_ref)


@functools.lru_cache(maxsize=None)
def _jit_rms():
    return jax.jit(lambda x, g, eps: ref.rmsnorm_ref(x, g, eps=eps),
                   static_argnums=(2,))


@functools.lru_cache(maxsize=None)
def _jit_ssd_seq():
    def _f(a, B, C, X, h0):
        # per-step decay recurrence (evaluator layout: a_t scalar per step)
        def _step(h, inp):
            a_t, b_t, c_t, x_t = inp
            h = a_t * h + jnp.outer(b_t, x_t)
            return h, h.T @ c_t
        return jax.lax.scan(_step, h0, (a, B, C, X))
    return jax.jit(_f)


def _intr_flash(Q, K, V, scale, n_q, P, O):
    q = _as_f32(Q)[None, :, None, :]
    k = _as_f32(K)[None, :, None, :]
    v = _as_f32(V)[None, :, None, :]
    mask = jnp.ones((1, q.shape[1], k.shape[1]), bool)
    if _INTERPRET:
        out = flash_attention_gqa(q, k, v, mask, sm_scale=float(scale),
                                  interpret=True)
    else:
        out = _jit_flash()(q, k, v, mask, float(scale))
    O[:] = np.asarray(out[0, :, 0, :], dtype=O.dtype)
    # P (the normalized probability matrix) is an ISAX-internal intermediate;
    # materialize it for evaluator parity with the reference program.
    s = (np.asarray(Q, np.float64) @ np.asarray(K, np.float64).T) * float(scale)
    e = np.exp(s - s.max(-1, keepdims=True))
    P[:] = e / e.sum(-1, keepdims=True)


def _intr_int8_matvec(Wq, X, s_w, n, C):
    x = _as_f32(X)
    w = jnp.asarray(np.asarray(Wq), jnp.int8)
    scale = jnp.full((w.shape[0],), float(s_w), jnp.float32)
    if _INTERPRET:
        out = int8_matmul(x, w, scale, interpret=True)
    else:
        out = _jit_int8()(x, w, scale)
    C[:] = np.asarray(out, dtype=C.dtype)


def _intr_ssd(A, B, C, X, T, H, Y):
    a = _as_f32(A)
    h, ys = _jit_ssd_seq()(a, _as_f32(B), _as_f32(C), _as_f32(X),
                           _as_f32(H[0]))
    Y[:] = np.asarray(ys, dtype=Y.dtype)
    H[0] = np.asarray(h, dtype=H.dtype)


def _intr_rmsnorm(Xn, G, eps, n, On):
    if _INTERPRET:
        out = rmsnorm(_as_f32(Xn), _as_f32(G), eps=float(eps),
                      interpret=True)
    else:
        out = _jit_rms()(_as_f32(Xn), _as_f32(G), float(eps))
    On[:] = np.asarray(out, dtype=On.dtype)


def register_kernel_intrinsics() -> None:
    """Register the e-graph intrinsics backed by these kernel datapaths."""
    from repro.core import offload
    offload.register_intrinsic("flash_attention", _intr_flash)
    offload.register_intrinsic("int8_matvec", _intr_int8_matvec)
    offload.register_intrinsic("ssd_step", _intr_ssd)
    offload.register_intrinsic("rmsnorm", _intr_rmsnorm)
