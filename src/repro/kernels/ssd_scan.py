"""SSD chunked-scan Pallas kernel (mamba2/zamba2's state-space-duality step).

Per (batch, head): the sequence is split into chunks of length Q; each grid
step computes the intra-chunk attention-like masked product (MXU work) plus
the inter-chunk contribution from the running state, then updates the state:

    y[q] = Σ_{k≤q} (C_q·B_k)·exp(acum_q − acum_k)·dt_k·x_k   (intra)
         + (C_q · h_prev) · exp(acum_q)                        (inter)
    h   ← exp(acum_last) · h_prev + Σ_k exp(acum_last − acum_k)·dt_k·B_k⊗x_k

The (N, P) running state lives in VMEM scratch across the sequential chunk
grid dim — the "warm" buffer of the interface model; x/B/C/dt chunks stream
as "cold" tiles.  Chunk length from ``core.kernel_synth.choose_ssd_blocks``.

This is the *unpipelined* baseline: chunks stream through BlockSpec copies.
``kernels.pipeline.ssd_scan_pipelined`` is the burst-DMA variant; the
``ops.ssd_scan`` wrapper routes between them on the synthesized cost-model
decision.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr,
                *, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q,)
    A = a_ref[0].astype(jnp.float32)           # () per-head
    B = b_ref[0].astype(jnp.float32)           # (Q, N)
    C = c_ref[0].astype(jnp.float32)           # (Q, N)

    a = dt * A                                  # (Q,) negative increments
    a_cum = jnp.cumsum(a)                       # (Q,)

    # intra-chunk
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    decay = jnp.exp(a_cum[:, None] - a_cum[None, :])
    Q = x.shape[0]
    tril = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    M = jnp.where(tril, scores * decay, 0.0)
    y_intra = jax.lax.dot_general(M * dt[None, :], x,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk from running state
    h_prev = state_scr[...]                     # (N, P)
    y_inter = jax.lax.dot_general(C * jnp.exp(a_cum)[:, None], h_prev,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update
    decay_last = jnp.exp(a_cum[-1] - a_cum)     # (Q,)
    wB = B * (decay_last * dt)[:, None]         # (Q, N)
    new_state = (jnp.exp(a_cum[-1]) * h_prev
                 + jax.lax.dot_general(wB, x, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32))
    state_scr[...] = new_state


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = False):
    """x: (BT,H,S,P), dt: (BT,H,S), A: (H,), B/C: (BT,S,N) → y: (BT,H,S,P).

    BT is the batch dim; B/C are shared across heads (indexed by batch only).
    S must be a multiple of `chunk` (callers pad like models/mamba2 does).
    """
    BT, H, S, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    grid = (BT, H, nc)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, ci: (b, h, ci)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,)),
            pl.BlockSpec((1, Q, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, ci: (b, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda b, h, ci: (b, h, ci, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
