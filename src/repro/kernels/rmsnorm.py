"""Fused RMSNorm Pallas kernel (the vector-unit ISAX: one pass over rows,
fp32 statistics, fused scale — avoids the separate mean/rsqrt/mul HLO ops)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)          # (br, d)
    g = g_ref[...].astype(jnp.float32)          # (d,)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g[None, :]).astype(o_ref.dtype)


def rmsnorm(x, g, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x: (R, d) — callers flatten leading dims; g: (d,)."""
    R, d = x.shape
    br = min(block_rows, R)
    assert R % br == 0, (R, br)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda ri: (ri, 0)),
            pl.BlockSpec((d,), lambda ri: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda ri: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, g)
