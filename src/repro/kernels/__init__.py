"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

One module per ISAX (``flash_attention``, ``int8_matmul``, ``rmsnorm``,
``ssd_scan``) plus ``pipeline`` (the burst-DMA multi-buffered variants of
the streaming kernels), ``ops`` (the public schedule-aware wrappers the
dispatcher binds) and ``ref`` (pure-jnp oracles every kernel is tested
against in interpret mode)."""
