"""Burst-DMA memory pipeline: multi-buffered async HBM→VMEM tile streaming.

The paper's headline hardware contribution is a burst DMA engine that keeps
the compute datapath fed; the TPU-native equivalent is explicit
``pltpu.make_async_copy`` multi-buffering.  The kernels in this module are
the pipelined variants of the baseline Pallas kernels: the "cold" operands
(K/V tiles for flash attention, quantized weight/activation tiles for the
int8 GEMM, x/B/C chunks for the SSD scan) stay in HBM (``memory_space=ANY``)
and are streamed into a ``depth``-deep rotating VMEM buffer by an explicit
DMA pipeline, overlapping the copy-in of tile ``i+1 .. i+depth-1`` with
compute on tile ``i``.

``BurstPipeline`` is the reusable streamer: the point-cloud kernels
(``pointcloud/kernels.py``) drive their X/feature tile streaming through
the same class, so the DMA schedule logic lives in exactly one place.

Buffer depth and tile shapes come from ``core.kernel_synth`` (which models
the transfer cost through the §4.1 interface-model recurrences and only
turns the pipeline on when both the interface model and the roofline
overlap bound predict a win); the dispatcher records the decision in its
compile cache, and ``benchmarks/bench_membw.py`` measures pipelined vs
unpipelined across memory-bound shapes.

Everything here runs under ``interpret=True`` on CPU — the Pallas
interpreter emulates DMA semaphores — so CI exercises the exact kernel
bodies that run on TPU.

Pipeline schedule (per sweep of the sequential grid dim, ``n_steps`` tiles):

    step 0      : start tiles 0..depth-2          (pipeline fill)
    step i      : start tile  i+depth-1  (if any) ─┐ overlapped with
                  wait  tile  i                    ─┘ compute on tile i
    step n-1    : nothing left to start; drain

Starts and waits balance exactly within one sweep, so the pipeline is clean
at every outer-grid-dim boundary (e.g. each new flash-attention q tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import (
    _finalize_flash_output,
    _init_flash_scratch,
    _online_softmax_update,
)

#: Default burst depth when a caller forces the pipeline on without a
#: synthesized schedule (two buffers = classic double buffering).
DEFAULT_DEPTH = 2


def use_pipeline(sched, override: bool | None, n_steps: int) -> bool:
    """Burst-pipeline routing rule shared by every op wrapper.

    The synthesized go/no-go decision (``sched.pipelined``) unless the
    caller forces it (``override``); a single streamed tile can never
    overlap, so it always takes the plain path.  Public home of the old
    ``kernels/ops._use_pipeline`` (it crossed module boundaries privately).
    """
    if n_steps < 2:
        return False
    return sched.pipelined if override is None else bool(override)


class BurstPipeline:
    """Multi-buffered HBM→VMEM tile streamer for use inside kernel bodies.

    Parameters
    ----------
    streams : sequence of ``(slice_fn, buf_ref)``
        One entry per cold operand.  ``slice_fn(t)`` must return the HBM
        source slice of tile ``t`` (``t`` may be a Python int during the
        pipeline fill or a traced scalar), shaped like one slot of
        ``buf_ref`` — a VMEM scratch of shape ``(depth, *tile_shape)``.
    sem : DMA semaphore array of shape ``(len(streams), depth)``.
    n_steps : static trip count of the streamed (sequential) grid dim.
    depth : static buffer depth ≥ 2.
    """

    def __init__(self, *, streams, sem, n_steps: int, depth: int):
        assert depth >= 2, "a burst pipeline needs at least two buffers"
        self.streams = tuple(streams)
        self.sem = sem
        self.n_steps = n_steps
        self.depth = depth

    def _copy(self, j: int, t):
        slice_fn, buf = self.streams[j]
        slot = t % self.depth
        return pltpu.make_async_copy(slice_fn(t), buf.at[slot],
                                     self.sem.at[j, slot])

    def _start_all(self, t) -> None:
        for j in range(len(self.streams)):
            self._copy(j, t).start()

    def stream_step(self, step):
        """Advance the pipeline by one grid step.

        Fills the pipeline at ``step == 0``, starts the copy of tile
        ``step + depth - 1`` (overwriting the slot the *previous* step
        finished computing on), then blocks until tile ``step`` has landed.
        Returns the buffer slot holding tile ``step``; the caller reads
        ``buf[slot]`` and computes while the started copies fly.
        """
        @pl.when(step == 0)
        def _fill():
            for d in range(min(self.depth - 1, self.n_steps)):
                self._start_all(d)

        nxt = step + self.depth - 1
        @pl.when(nxt < self.n_steps)
        def _prefetch():
            self._start_all(nxt)

        for j in range(len(self.streams)):
            self._copy(j, step).wait()
        return step % self.depth


# ---------------------------------------------------------------------------
# Flash attention (K/V tiles streamed)
# ---------------------------------------------------------------------------

def _flash_pipelined_kernel(q_ref, k_hbm, v_hbm, mask_ref, o_ref,
                            k_buf, v_buf, sem, m_scr, l_scr, acc_scr,
                            *, sm_scale: float, n_kv: int, block_k: int,
                            depth: int, n_groups: int):
    b, h, ki = pl.program_id(0), pl.program_id(1), pl.program_id(3)
    kvh = h // n_groups
    pipe = BurstPipeline(
        streams=(
            (lambda t: k_hbm.at[b, pl.ds(t * block_k, block_k), kvh, :],
             k_buf),
            (lambda t: v_hbm.at[b, pl.ds(t * block_k, block_k), kvh, :],
             v_buf),
        ),
        sem=sem, n_steps=n_kv, depth=depth)

    @pl.when(ki == 0)
    def _init():
        _init_flash_scratch(m_scr, l_scr, acc_scr)

    slot = pipe.stream_step(ki)
    _online_softmax_update(
        q_ref[0, :, 0, :].astype(jnp.float32),      # (bq, hd)
        k_buf[slot].astype(jnp.float32),            # (bk, hd)
        v_buf[slot].astype(jnp.float32),            # (bk, hd)
        mask_ref[0, :, :], sm_scale, m_scr, l_scr, acc_scr)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        _finalize_flash_output(o_ref, l_scr, acc_scr)


def flash_attention_pipelined(q, k, v, mask, *, sm_scale: float,
                              block_q: int = 128, block_k: int = 128,
                              depth: int = DEFAULT_DEPTH,
                              interpret: bool = False):
    """Burst-DMA flash attention: K/V tiles streamed HBM→VMEM explicitly.

    Same contract as ``flash_attention.flash_attention`` — q (B,S,H,hd),
    k/v (B,T,K,hd), mask (1|B,S,T) bool → (B,S,H,hd) — but the K/V operands
    bypass BlockSpec staging and flow through a ``depth``-deep rotating
    buffer driven by ``BurstPipeline``.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bq, bk = min(block_q, S), min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    mask_b = mask.shape[0]
    return pl.pallas_call(
        functools.partial(_flash_pipelined_kernel, sm_scale=sm_scale,
                          n_kv=nk, block_k=bk, depth=depth, n_groups=G),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # K stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),   # V stays in HBM
            pl.BlockSpec((1, bq, bk),
                         lambda b, h, qi, ki, mb=mask_b:
                         (b if mb > 1 else 0, qi, ki)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((depth, bk, hd), k.dtype),
            pltpu.VMEM((depth, bk, hd), v.dtype),
            pltpu.SemaphoreType.DMA((2, depth)),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)


# ---------------------------------------------------------------------------
# Int8-weight matmul (weight + activation tiles streamed)
# ---------------------------------------------------------------------------

def _int8_mm_pipelined_kernel(x_hbm, w_hbm, s_ref, o_ref,
                              x_buf, w_buf, sem, acc_scr,
                              *, n_k: int, block_m: int, block_n: int,
                              block_k: int, depth: int):
    mi, ni, ki = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    pipe = BurstPipeline(
        streams=(
            (lambda t: x_hbm.at[pl.ds(mi * block_m, block_m),
                                pl.ds(t * block_k, block_k)], x_buf),
            (lambda t: w_hbm.at[pl.ds(ni * block_n, block_n),
                                pl.ds(t * block_k, block_k)], w_buf),
        ),
        sem=sem, n_steps=n_k, depth=depth)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    slot = pipe.stream_step(ki)
    x = x_buf[slot].astype(jnp.float32)             # (bm, bk)
    w = w_buf[slot].astype(jnp.float32)             # (bn, bk) int8 → f32
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finalize():
        scale = s_ref[...].astype(jnp.float32)       # (bn,)
        o_ref[...] = (acc_scr[...] * scale[None, :]).astype(o_ref.dtype)


def int8_matmul_pipelined(x, wq, scale, *, block_m: int = 128,
                          block_n: int = 128, block_k: int = 512,
                          depth: int = DEFAULT_DEPTH,
                          interpret: bool = False, out_dtype=None):
    """Burst-DMA int8 GEMM: weight and activation tiles streamed HBM→VMEM.

    Same contract as ``int8_matmul.int8_matmul`` — x (M,K) float, wq (N,K)
    int8, scale (N,) → (M,N).  The int8 weight tiles stream at half the DMA
    bytes of bf16, which is exactly what the interface model rewards.
    """
    M, K = x.shape
    N = wq.shape[0]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (x.shape, wq.shape)
    grid = (M // bm, N // bn, K // bk)
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        functools.partial(_int8_mm_pipelined_kernel, n_k=grid[2],
                          block_m=bm, block_n=bn, block_k=bk, depth=depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),   # x stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),   # wq stays in HBM
            pl.BlockSpec((bn,), lambda mi, ni, ki: (ni,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((depth, bm, bk), x.dtype),
            pltpu.VMEM((depth, bn, bk), wq.dtype),
            pltpu.SemaphoreType.DMA((2, depth)),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(x, wq, scale)


# ---------------------------------------------------------------------------
# SSD chunked scan (x/B/C chunks streamed; running state stays warm in VMEM)
# ---------------------------------------------------------------------------

def _ssd_pipelined_kernel(dt_ref, a_ref, x_hbm, b_hbm, c_hbm, y_ref,
                          x_buf, b_buf, c_buf, sem, state_scr,
                          *, n_chunks: int, chunk: int, depth: int):
    b, h, ci = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    pipe = BurstPipeline(
        streams=(
            (lambda t: x_hbm.at[b, h, pl.ds(t * chunk, chunk), :], x_buf),
            (lambda t: b_hbm.at[b, pl.ds(t * chunk, chunk), :], b_buf),
            (lambda t: c_hbm.at[b, pl.ds(t * chunk, chunk), :], c_buf),
        ),
        sem=sem, n_steps=n_chunks, depth=depth)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    slot = pipe.stream_step(ci)
    x = x_buf[slot].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q,)
    A = a_ref[0].astype(jnp.float32)           # () per-head
    B = b_buf[slot].astype(jnp.float32)        # (Q, N)
    C = c_buf[slot].astype(jnp.float32)        # (Q, N)

    a = dt * A
    a_cum = jnp.cumsum(a)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    decay = jnp.exp(a_cum[:, None] - a_cum[None, :])
    Q = x.shape[0]
    tril = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    M = jnp.where(tril, scores * decay, 0.0)
    y_intra = jax.lax.dot_general(M * dt[None, :], x,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    h_prev = state_scr[...]
    y_inter = jax.lax.dot_general(C * jnp.exp(a_cum)[:, None], h_prev,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    decay_last = jnp.exp(a_cum[-1] - a_cum)
    wB = B * (decay_last * dt)[:, None]
    state_scr[...] = (jnp.exp(a_cum[-1]) * h_prev
                      + jax.lax.dot_general(wB, x, (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))


def ssd_scan_pipelined(x, dt, A, B, C, *, chunk: int = 128,
                       depth: int = DEFAULT_DEPTH, interpret: bool = False):
    """Burst-DMA SSD scan: x/B/C chunks streamed HBM→VMEM explicitly.

    Same contract as ``ssd_scan.ssd_scan`` — x (BT,H,S,P), dt (BT,H,S),
    A (H,), B/C (BT,S,N) → y (BT,H,S,P); S must be a multiple of ``chunk``.
    The (N,P) running state stays warm in VMEM scratch across the chunk
    sweep while the streamed chunks rotate through the burst buffers.
    """
    BT, H, S, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    return pl.pallas_call(
        functools.partial(_ssd_pipelined_kernel, n_chunks=nc, chunk=Q,
                          depth=depth),
        grid=(BT, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q), lambda b, h, ci: (b, h, ci)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # x stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),   # B stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),   # C stays in HBM
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda b, h, ci: (b, h, ci, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[
            pltpu.VMEM((depth, Q, P), x.dtype),
            pltpu.VMEM((depth, Q, N), B.dtype),
            pltpu.VMEM((depth, Q, N), C.dtype),
            pltpu.SemaphoreType.DMA((3, depth)),
            pltpu.VMEM((N, P), jnp.float32),
        ],
        interpret=interpret,
    )(dt, A, x, B, C)
