"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, mask, *, sm_scale: float):
    """q: (B,S,H,hd), k/v: (B,T,K,hd), mask: (1|B,S,T) → (B,S,H,hd)."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, kf) * sm_scale
    s = jnp.where(mask[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows: softmax of all -1e30 is uniform; zero them like the
    # kernel (denominator clamp) does
    any_valid = jnp.any(mask, axis=-1)[:, None, None, :, None]
    p = jnp.where(any_valid, p, 0.0)
    o = jnp.einsum("bkgst,btkd->bskgd", p, vf)
    return o.reshape(B, S, H, hd).astype(q.dtype)


def int8_matmul_ref(x, wq, scale):
    """x: (M,K), wq: (N,K) int8, scale: (N,) → (M,N)."""
    y = x.astype(jnp.float32) @ wq.astype(jnp.float32).T
    return (y * scale.astype(jnp.float32)[None, :]).astype(x.dtype)


def ssd_scan_ref(x, dt, A, B, C):
    """Naive recurrence.  x: (BT,H,S,P), dt: (BT,H,S), A: (H,), B/C: (BT,S,N)."""
    BT, H, S, P = x.shape

    def _step(h, inputs):
        x_t, dt_t, b_t, c_t = inputs  # (BT,H,P), (BT,H), (BT,N), (BT,N)
        decay = jnp.exp(dt_t * A[None, :])                     # (BT,H)
        h = (decay[..., None, None] * h
             + jnp.einsum("bh,bn,bhp->bhnp", dt_t, b_t, x_t))
        y = jnp.einsum("bn,bhnp->bhp", c_t, h)
        return h, y

    h0 = jnp.zeros((BT, H, B.shape[-1], P), jnp.float32)
    xs = (x.transpose(2, 0, 1, 3).astype(jnp.float32),
          dt.transpose(2, 0, 1).astype(jnp.float32),
          B.transpose(1, 0, 2).astype(jnp.float32),
          C.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(_step, h0, xs)
    return ys.transpose(1, 2, 0, 3).astype(x.dtype)  # (BT,H,S,P)


def rmsnorm_ref(x, g, *, eps: float = 1e-6):
    """RMSNorm oracle: x (R,d) · rsqrt(mean(x²)) · g, computed in f32."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * g.astype(jnp.float32)).astype(x.dtype)
