"""Flash-attention Pallas TPU kernel (the LLM-inference ISAX of paper §6.5,
TPU-native: VMEM-staged KV streaming instead of BRAM scratchpads).

Tiling and buffering come from the interface-aware synthesis flow
(``core.kernel_synth.choose_flash_blocks``): Q tiles are "warm" (persistent
across the kv loop), K/V tiles are "cold" (streamed), mirroring the paper's
cache_hint machinery.

Layout: q (B, S, H, hd), k/v (B, T, K, hd) with GQA head folding h → h // G
in the BlockSpec index map.  Grid (B, H, nq, nk): the last grid dim iterates
sequentially on TPU, so the running max / denominator / output accumulator
live in VMEM scratch and are re-initialized at nk == 0.

This is the *unpipelined* baseline: K/V stream through BlockSpec copies.
``kernels.pipeline.flash_attention_pipelined`` is the burst-DMA variant
(explicit multi-buffered ``make_async_copy`` K/V streaming); the
``ops.flash_attention_gqa`` wrapper routes between them on the synthesized
cost-model decision.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _init_flash_scratch(m_scr, l_scr, acc_scr):
    """Reset the online-softmax running stats at the start of a kv sweep."""
    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)


def _online_softmax_update(q, k, v, mask, sm_scale,
                           m_scr, l_scr, acc_scr):
    """One flash tile update: masked scores → online softmax → scratch.

    ``q``/``k``/``v`` are f32 tiles, ``mask`` (bq, bk) bool.  Shared by the
    BlockSpec baseline, the int8-KV variant, and the burst-DMA pipelined
    kernel (``kernels/pipeline.py``) so the numerically delicate masked-row
    handling lives in exactly one place.
    """
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[...]                              # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    # guard fully-masked rows (m == NEG_INF): exp(NEG_INF - NEG_INF) = 1
    # would pollute l; use alpha = exp(m_prev - m_new) with masked-safe forms.
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _finalize_flash_output(o_ref, l_scr, acc_scr):
    """Divide the accumulator by the running denominator (masked-row safe)."""
    denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
    o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _flash_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, sm_scale: float, n_kv: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        _init_flash_scratch(m_scr, l_scr, acc_scr)

    _online_softmax_update(
        q_ref[0, :, 0, :].astype(jnp.float32),       # (bq, hd)
        k_ref[0, :, 0, :].astype(jnp.float32),       # (bk, hd)
        v_ref[0, :, 0, :].astype(jnp.float32),       # (bk, hd)
        mask_ref[0, :, :], sm_scale, m_scr, l_scr, acc_scr)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        _finalize_flash_output(o_ref, l_scr, acc_scr)


def _flash_kernel_int8kv(q_ref, k_ref, v_ref, ks_ref, vs_ref, mask_ref,
                         o_ref, m_scr, l_scr, acc_scr,
                         *, sm_scale: float, n_kv: int):
    """int8-KV variant (the paper's §6.5 quantized-attention ISAX): K/V
    stream HBM→VMEM as int8 (half the DMA bytes — what the interface model
    rewards) and dequantize against per-head scales INSIDE the tile, so the
    bf16 cache is never materialized."""
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        _init_flash_scratch(m_scr, l_scr, acc_scr)

    _online_softmax_update(
        q_ref[0, :, 0, :].astype(jnp.float32),
        k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[0],
        v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[0],
        mask_ref[0, :, :], sm_scale, m_scr, l_scr, acc_scr)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        _finalize_flash_output(o_ref, l_scr, acc_scr)


def flash_attention_int8kv(q, k8, v8, k_scale, v_scale, mask, *,
                           sm_scale: float, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q: (B,S,H,hd) float; k8/v8: (B,T,K,hd) int8; k_scale/v_scale: (K,)
    per-kv-head fp32 scales; mask: (1|B,S,T) bool → (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, K = k8.shape[1], k8.shape[2]
    G = H // K
    bq, bk = min(block_q, S), min(block_k, T)
    assert S % bq == 0 and T % bk == 0
    nq, nk = S // bq, T // bk
    mask_b = mask.shape[0]
    return pl.pallas_call(
        functools.partial(_flash_kernel_int8kv, sm_scale=sm_scale, n_kv=nk),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1,), lambda b, h, qi, ki, G=G: (h // G,)),
            pl.BlockSpec((1,), lambda b, h, qi, ki, G=G: (h // G,)),
            pl.BlockSpec((1, bq, bk),
                         lambda b, h, qi, ki, mb=mask_b:
                         (b if mb > 1 else 0, qi, ki)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k8, v8, k_scale, v_scale, mask)


def flash_attention(q, k, v, mask, *, sm_scale: float,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B,S,H,hd), k/v: (B,T,K,hd), mask: (1|B,S,T) bool → (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    mask_b = mask.shape[0]

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, sm_scale=sm_scale, n_kv=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bq, bk),
                         lambda b, h, qi, ki, mb=mask_b:
                         (b if mb > 1 else 0, qi, ki)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)
    return out
