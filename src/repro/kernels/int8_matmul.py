"""Int8-weight matmul Pallas kernel (paper §6.5: 8-bit quantized Llama
inference; the mgf2mm-style "matrix engine" ISAX in TPU form).

y[M,N] = (x[M,K] @ wq[N,K]^T) * scale[N]  with int8 weights dequantized
against a per-output-channel fp32 scale inside the kernel (weights stream
HBM→VMEM as int8 — halving DMA bytes vs bf16, which is what the interface
model rewards).

Grid (nm, nn, nk): accumulate in f32 VMEM scratch over the sequential k dim.
Tile shapes come from ``core.kernel_synth.choose_matmul_blocks``.

This is the *unpipelined* baseline: tiles stream through BlockSpec copies.
``kernels.pipeline.int8_matmul_pipelined`` is the burst-DMA variant; the
``ops.int8_matmul`` wrapper routes between them on the synthesized
cost-model decision.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _int8_mm_kernel(x_ref, w_ref, s_ref, o_ref, acc_scr, *, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)          # (bm, bk)
    w = w_ref[...].astype(jnp.float32)          # (bn, bk) int8 → f32
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finalize():
        scale = s_ref[...].astype(jnp.float32)   # (bn,)
        o_ref[...] = (acc_scr[...] * scale[None, :]).astype(o_ref.dtype)


def int8_matmul(x, wq, scale, *, block_m: int = 128, block_n: int = 128,
                block_k: int = 512, interpret: bool = False,
                out_dtype=None):
    """x: (M,K) float, wq: (N,K) int8, scale: (N,) → (M,N)."""
    M, K = x.shape
    N = wq.shape[0]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (x.shape, wq.shape)
    grid = (M // bm, N // bn, K // bk)
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        functools.partial(_int8_mm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bn, bk), lambda mi, ni, ki: (ni, ki)),
            pl.BlockSpec((bn,), lambda mi, ni, ki: (ni,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, wq, scale)
