"""Layer-op tracer: capture each hot op as a ``core/expr`` mini-IR program.

This is the front half of the dispatch pipeline (trace → saturate → match →
extract → kernel).  Every hot op the models execute — GQA attention, paged
decode attention, RMSNorm, int8/bf16 matmul, the SSD scan — has a
software-side loop-nest description here.  The spellings are deliberately
*divergent* from the ISAX library's semantics (scale placed inside the
matvec, softmax without the max shift, rsqrt via recip∘sqrt): matching is a
theorem proved by equality saturation plus skeleton/component matching, not
string equality, which is exactly the paper's retargetability claim.

``OpKey`` is the compile-cache key: one entry per (op, shape, dtype,
backend).  Shape tuples are per-op conventions (documented on ``op_key``)
chosen so that every distinct kernel-schedule decision gets its own entry
while batch-irrelevant details are folded away.
"""

from __future__ import annotations

import dataclasses
import functools

from repro.core.expr import Term, arr, const, for_, var

#: op name → the ISAX the compiler is expected to be able to target (None
#: means "no specialized datapath exists" — a deliberate negative control
#: whose keys must lower to the XLA reference).
TARGET_ISAX: dict[str, str | None] = {
    "attention": "flash_attention",
    "attention_decode": "flash_attention",
    "attention_paged": "flash_attention",
    "rmsnorm": "rmsnorm",
    "matmul": None,
    "int8_matmul": "int8_matvec",
    "ssd_scan": "ssd_step",
    "fps": "fps",
    "ball_query": "ball_query",
    "group_aggregate": "group_agg",
}

#: op name → trace-table entry (attention variants share one program: the
#: e-graph outcome is shape-independent; only the schedule decision differs).
_TRACE_KIND = {
    "attention": "attention",
    "attention_decode": "attention",
    "attention_paged": "attention",
    "rmsnorm": "rmsnorm",
    "matmul": "matmul",
    "int8_matmul": "int8_matmul",
    "ssd_scan": "ssd_scan",
    "fps": "fps",
    "ball_query": "ball_query",
    "group_aggregate": "group_aggregate",
}


@dataclasses.dataclass(frozen=True)
class OpKey:
    """Compile-cache key: one persistent entry per (op, shape, dtype, backend).

    Shape conventions:
      attention / attention_decode / attention_paged: (B, S, H, K, T, hd)
      rmsnorm:     (rows, d)
      matmul:      (rows, d_in, d_out)
      int8_matmul: (rows, d_in, d_out)
      ssd_scan:    (b, s, H, P, N)
      fps:             (B, n_points, n_samples)
      ball_query:      (B, n_points, n_centers, k)
      group_aggregate: (B, n_points, n_centers, k, channels)
    """

    op: str
    shape: tuple[int, ...]
    dtype: str
    backend: str

    def __post_init__(self):
        if self.op not in TARGET_ISAX:
            raise ValueError(f"unknown dispatch op {self.op!r}; "
                             f"known: {sorted(TARGET_ISAX)}")


def trace_kind(op: str) -> str:
    """Trace kind an op's e-graph outcome is memoized under (attention
    prefill/decode/paged all share the ``attention`` saturation run)."""
    return _TRACE_KIND[op]


def _attention_program() -> Term:
    """Row-blocked attention, AF+RF-divergent: the scale rides inside the
    matvec and the softmax omits the max shift (the bench's robustness
    variant) — internal rewrites must recover the flash ISAX form."""
    i = var("i")
    q = ("load", arr("Q"), i)
    s = ("/",
         ("exp", ("matvec", arr("K"), ("*", var("scale"), q))),
         ("rowsum", ("exp", ("matvec", arr("K"), ("*", var("scale"), q)))))
    return for_("i", const(0), var("n_q"), const(1),
                ("store", arr("P"), i, s),
                ("store", arr("O"), i,
                 ("matvec", ("transpose", arr("V")), ("load", arr("P"), i))))


def _rmsnorm_program() -> Term:
    """RMSNorm with rsqrt spelled as recip∘sqrt (RF-divergent)."""
    i = var("i")
    x = ("load", arr("Xn"), i)
    return for_("i", const(0), var("n"), const(1),
                ("store", arr("On"), i,
                 ("*", ("*", x, ("recip", ("sqrt",
                                           ("+", ("rowmean", ("*", x, x)),
                                            var("eps"))))),
                  arr("G"))))


def _matmul_program() -> Term:
    """Plain row-wise matmul — no quantization scale, so it must NOT match
    the int8_matvec ISAX (the library has no bf16 GEMM datapath)."""
    i = var("i")
    return for_("i", const(0), var("n"), const(1),
                ("store", arr("C"), i,
                 ("matvec", arr("W"), ("load", arr("X"), i))))


def _int8_matmul_program() -> Term:
    i = var("i")
    return for_("i", const(0), var("n"), const(1),
                ("store", arr("C"), i,
                 ("*", var("s_w"),
                  ("matvec", arr("Wq"), ("load", arr("X"), i)))))


def _ssd_program() -> Term:
    """SSD recurrence with the loop-carried state dependence through H."""
    t = var("t")
    upd = ("+",
           ("*", ("load", arr("A"), t), ("load", arr("H"), const(0))),
           ("outer", ("load", arr("B"), t), ("load", arr("X"), t)))
    out = ("matvec", ("transpose", ("load", arr("H"), const(0))),
           ("load", arr("C"), t))
    return for_("t", const(0), var("T"), const(1),
                ("store", arr("H"), const(0), upd),
                ("store", arr("Y"), t, out))


def _sqdist_expanded(a, b):
    """Row-wise squared distance in the *expanded* spelling
    ‖a‖² + (‖b‖² − 2·a·b): AF-divergent from the ISAXes' compact
    rowsum((a−b)²) form — ``rewrites.sqdist-expand`` must bridge the gap."""
    return ("+", ("rowsum", ("*", a, a)),
            ("-", ("rowsum", ("*", b, b)),
             ("*", ("const:2",), ("rowsum", ("*", a, b)))))


def _fps_program():
    """Farthest-point sampling with the distance spelled expanded; the
    loop-carried dependences (S feeds the same iteration's distance update,
    D feeds the next iteration's argmax) must survive saturation."""
    s = var("s")
    picked = ("load", arr("Xp"), ("load", arr("Sp"), s))
    return for_("s", const(0), var("n_s"), const(1),
                ("store", arr("Sp"), s,
                 ("argmax", ("load", arr("Dp"), const(0)))),
                ("store", arr("Dp"), const(0),
                 ("min", ("load", arr("Dp"), const(0)),
                  _sqdist_expanded(arr("Xp"), picked))))


def _ball_query_program():
    """Ball query with the expanded distance spelling (same AF divergence
    as fps, exercised under a different skeleton)."""
    j = var("j")
    return for_("j", const(0), var("n_c"), const(1),
                ("store", arr("Gq"), j,
                 ("ballsel",
                  _sqdist_expanded(arr("Xp"), ("load", arr("Cn"), j)),
                  var("r2"), var("kk"))))


def _group_agg_program():
    """Grouped aggregation with max-pool spelled as neg∘colmin∘neg
    (RF-divergent; ``rewrites.colmax-neg-colmin`` recovers the ISAX form)."""
    j = var("j")
    gathered = ("gather", arr("Fg"), ("load", arr("Gq"), j))
    return for_("j", const(0), var("n_c"), const(1),
                ("store", arr("Ag"), j,
                 ("neg", ("colmin", ("neg", gathered)))))


_PROGRAMS = {
    "attention": _attention_program,
    "rmsnorm": _rmsnorm_program,
    "matmul": _matmul_program,
    "int8_matmul": _int8_matmul_program,
    "ssd_scan": _ssd_program,
    "fps": _fps_program,
    "ball_query": _ball_query_program,
    "group_aggregate": _group_agg_program,
}


@functools.lru_cache(maxsize=None)
def trace_term(kind: str) -> Term:
    """The software-side program for one trace kind (memoized: terms are
    shape-independent, so each kind is built once per process)."""
    return _PROGRAMS[kind]()
