"""Compile-cache keys and registry-backed trace lookups.

This is the front half of the dispatch pipeline (trace → saturate → match →
extract → kernel).  The *trace programs themselves* — the deliberately
divergent software-side loop nests for every hot op — live on the
``repro.targets`` domain packages now (``IsaxSpec.trace_program``); this
module keeps the cache key (:class:`OpKey`) and thin registry-backed
views so historical imports (``TARGET_ISAX``, ``trace_kind``,
``trace_term``) keep working and can never drift from the registry.

``OpKey`` is the compile-cache key: one entry per (op, shape, dtype,
backend).  Shape tuples are per-op conventions (documented on ``OpKey``)
chosen so that every distinct kernel-schedule decision gets its own entry
while batch-irrelevant details are folded away.  Op names are validated
against the dispatcher's registry at lowering time (not here), so keys for
custom-registry domains construct cleanly.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Mapping

from repro.core.expr import Term
from repro.targets import default_registry


class _TargetIsaxView(Mapping):
    """Live ``op → target-ISAX-or-None`` mapping over the global registry.

    Replaces the old hand-maintained module dict: iteration order is
    registration order, membership tracks whatever domains are registered,
    and a ``None`` value still marks a deliberate negative control."""

    def __getitem__(self, op: str):
        return default_registry().target_isax(op)

    def __iter__(self):
        return iter(default_registry().ops())

    def __len__(self):
        return len(default_registry().ops())

    def __repr__(self):
        return f"TARGET_ISAX({dict(self)!r})"


#: op name → the ISAX the compiler is expected to be able to target (None
#: means "no specialized datapath exists" — a deliberate negative control
#: whose keys must lower to the XLA reference).  Derived live from the
#: ``repro.targets`` registry.
TARGET_ISAX: Mapping = _TargetIsaxView()


@dataclasses.dataclass(frozen=True)
class OpKey:
    """Compile-cache key: one persistent entry per (op, shape, dtype, backend).

    Shape conventions (built-in domains):
      attention / attention_decode / attention_paged: (B, S, H, K, T, hd)
      rmsnorm:     (rows, d)
      matmul:      (rows, d_in, d_out)
      int8_matmul: (rows, d_in, d_out)
      ssd_scan:    (b, s, H, P, N)
      fps:             (B, n_points, n_samples)
      ball_query:      (B, n_points, n_centers, k)
      group_aggregate: (B, n_points, n_centers, k, channels)

    New domains document their conventions on their ``IsaxSpec`` entries.
    """

    op: str
    shape: tuple[int, ...]
    dtype: str
    backend: str

    def __post_init__(self):
        if not self.op or not isinstance(self.op, str):
            raise ValueError(f"OpKey.op must be a non-empty string, "
                             f"got {self.op!r}")


def trace_kind(op: str) -> str:
    """Trace kind an op's e-graph outcome is memoized under (attention
    prefill/decode/paged all share the ``attention`` saturation run).

    Registry-backed: the *engine* memoizes on the spec object itself (two
    domains can never alias a kind string); this helper only reports the
    human-readable label."""
    return default_registry().op_spec(op).trace_kind


def trace_term(kind: str) -> Term:
    """The software-side program for one trace kind, resolved through the
    registry (terms are shape-independent)."""
    return default_registry().spec_for_kind(kind).trace_program()
