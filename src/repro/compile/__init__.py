"""Compiler-driven kernel dispatch (trace → saturate → match → extract →
kernel).

The models' hot ops are captured into the ``core/expr`` mini-IR
(``trace``), lowered through equality saturation + skeleton/component ISAX
matching with a persistent in-process compile cache (``dispatch``), and
executed through the backend policy object threaded into models and serve
engines (``config.LoweringConfig``).
"""

from repro.compile.config import (
    VALID_BACKENDS,
    LoweringConfig,
    default_lowering,
    get_default_backend,
    set_default_backend,
    set_default_lowering,
)
from repro.compile.dispatch import (
    CompileRecord,
    Dispatcher,
    MatchOutcome,
    get_dispatcher,
)
from repro.compile.trace import TARGET_ISAX, OpKey

__all__ = [
    "VALID_BACKENDS",
    "LoweringConfig",
    "default_lowering",
    "get_default_backend",
    "set_default_backend",
    "set_default_lowering",
    "CompileRecord",
    "Dispatcher",
    "MatchOutcome",
    "get_dispatcher",
    "TARGET_ISAX",
    "OpKey",
]
