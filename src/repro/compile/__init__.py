"""Compiler-driven kernel dispatch (trace → saturate → match → extract →
kernel) over the declarative ``repro.targets`` registry.

The models' hot ops are captured into the ``core/expr`` mini-IR (trace
programs live on the registered ``IsaxSpec`` entries), lowered through
equality saturation + skeleton/component ISAX matching by the generic
registry engine with a persistent in-process compile cache (``dispatch``),
and executed through the backend policy object threaded into models and
serve engines (``config.LoweringConfig``).

Public entry points of the retargetable lowering API:

* ``lower(op, *, shape, dtype, backend=None)`` — one-shot compile-cache
  lookup through the global registry.
* ``LoweringConfig.from_registry(backend, registry=...)`` — a threadable
  policy, optionally bound to an isolated :class:`TargetRegistry`.
"""

from repro.compile.config import (
    VALID_BACKENDS,
    LoweringConfig,
    default_lowering,
    get_default_backend,
    lower,
    set_default_backend,
    set_default_lowering,
)
from repro.compile.dispatch import (
    CompileRecord,
    Dispatcher,
    MatchOutcome,
    get_dispatcher,
)
from repro.compile.trace import TARGET_ISAX, OpKey

__all__ = [
    "VALID_BACKENDS",
    "LoweringConfig",
    "default_lowering",
    "get_default_backend",
    "lower",
    "set_default_backend",
    "set_default_lowering",
    "CompileRecord",
    "Dispatcher",
    "MatchOutcome",
    "get_dispatcher",
    "TARGET_ISAX",
    "OpKey",
]
