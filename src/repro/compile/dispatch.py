"""Compiler-driven kernel dispatch: the lowering pass behind every hot op.

For each ``OpKey`` (op, shape, dtype, backend) the dispatcher runs the full
retargetable-compiler flow over the traced software program — equality
saturation (``core/rewrites``) interleaved with ISAX-guided external loop
transforms, then skeleton/component matching (``core/matching``) — and
decides whether to extract an ``isax:*`` kernel call (a Pallas entry point
from ``kernels/ops.py``, with a schedule from ``core/kernel_synth``) or fall
back to the XLA reference.  Decisions live in a persistent in-process
compile cache, so the e-graph work is paid once per op kind and the
schedule/tileability decision once per shape; later jit traces of the same
op hit the cache.

Kernel entry points are resolved here, at dispatch/compile time (module
import), never lazily inside a forward function: a ``CompileRecord`` carries
the bound callable.

Invariants:

* **Cache key** — the compile cache is keyed on the full
  ``OpKey(op, shape, dtype, backend)`` tuple and nothing else; two lookups
  with equal keys always return the *same* ``CompileRecord`` object, and
  any input property that should change the lowering (a new shape, a dtype
  switch, a different backend preference) must be part of the key.
* **E-graph amortization** — saturation/matching outcomes are memoized per
  *trace kind* (attention prefill/decode/paged share one run); schedules
  and impl decisions are per key.  ``lower`` is called at jit-trace time
  only, so steady-state inference never pays a dispatch cost.
* **Recorded schedules are the executed schedules** — the schedule dict in
  a ``CompileRecord`` (tiles, buffer depth, burst-pipeline go/no-go) uses
  the same ``core.kernel_synth`` entry points the kernel wrappers consult,
  so what ``BENCH_compile.json`` reports is what the kernel ran.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.compile.trace import TARGET_ISAX, OpKey, trace_kind, trace_term
from repro.core.interface_model import TPU_VMEM_BUDGET
from repro.core.kernel_synth import (
    choose_ball_blocks,
    choose_flash_blocks,
    choose_fps_blocks,
    choose_group_blocks,
    choose_matmul_blocks,
    choose_ssd_blocks,
    fps_vmem_bytes,
)
from repro.core.offload import compile_program, isax_library
from repro.kernels import ops as kops
from repro.kernels.ops import _down_pow2
from repro.pointcloud import ops as pcops

#: Minimum query rows for the flash ISAX: the row-blocked skeleton needs at
#: least one sublane-worth of rows; single-token decode tiles degenerate.
_MIN_QUERY_TILE = 8

#: ISAX name → resolved kernel entry point (once, at import).
_KERNELS: dict[str, Callable] = {
    "flash_attention": kops.flash_attention_gqa,
    "rmsnorm": kops.rmsnorm,
    "int8_matvec": kops.int8_matmul,
    "ssd_step": kops.ssd_scan,
    "fps": pcops.farthest_point_sample,
    "ball_query": pcops.ball_query,
    "group_agg": pcops.group_aggregate,
}


@dataclasses.dataclass(frozen=True)
class MatchOutcome:
    """E-graph compilation result for one trace kind (shape-independent)."""

    matched: tuple[str, ...]
    internal_rewrites: int
    external_rewrites: int
    initial_enodes: int
    saturated_enodes: int


@dataclasses.dataclass
class CompileRecord:
    """One compile-cache entry: the match result and lowering decision for a
    single (op, shape, dtype, backend) tuple."""

    key: OpKey
    impl: str                      # 'isax' | 'chunked' | 'reference'
    matched: tuple[str, ...]       # every ISAX the e-graph pipeline matched
    target: Optional[str]          # the ISAX this op is expected to target
    kernel_fn: Optional[Callable]  # resolved entry point when impl == 'isax'
    schedule: Optional[dict]       # synthesis-chosen tiling when impl == 'isax'
    note: str                      # human-readable decision rationale
    outcome: MatchOutcome
    hits: int = 0

    @property
    def target_matched(self) -> bool:
        """True iff the e-graph pipeline matched this op's target ISAX."""
        return self.target is not None and self.target in self.matched

    def row(self) -> dict:
        """Flatten the record for the ``BENCH_compile.json`` artifact."""
        return {
            "op": self.key.op, "shape": list(self.key.shape),
            "dtype": self.key.dtype, "backend": self.key.backend,
            "impl": self.impl, "matched": list(self.matched),
            "target": self.target, "schedule": self.schedule,
            "note": self.note, "hits": self.hits,
            "internal_rewrites": self.outcome.internal_rewrites,
            "external_rewrites": self.outcome.external_rewrites,
            "saturated_enodes": self.outcome.saturated_enodes,
        }


def _pipeline_fields(sched) -> dict:
    """Burst-DMA pipeline decision recorded in the compile-cache entry (and
    therefore in ``BENCH_compile.json`` via ``CompileRecord.row``): whether
    the kernel streams its cold operands through ``kernels/pipeline.py``
    and the conservatively-predicted gain (the depth is the schedule's
    ``buffering`` field, recorded alongside)."""
    return {"pipelined": sched.pipelined,
            "pipeline_gain": round(sched.pipeline_gain, 3),
            "est_serial_cycles": sched.est_serial_cycles}


def _attention_schedule(key: OpKey):
    B, S, H, K, T, hd = key.shape
    if S < _MIN_QUERY_TILE:
        return None, f"degenerate query tile (S={S} < {_MIN_QUERY_TILE})"
    # itemsize (not a name heuristic) so the recorded schedule matches the
    # one the kernel wrapper re-derives from q.dtype.itemsize; ml_dtypes
    # (pulled in via the kernels import) registers bfloat16 with numpy
    try:
        dtype_bytes = np.dtype(key.dtype).itemsize
    except TypeError:
        dtype_bytes = 2 if key.dtype.endswith("16") else 4
    sched = choose_flash_blocks(S, T, hd, dtype_bytes)
    bq = _down_pow2(S, sched.block("q")[0])
    bk = _down_pow2(T, sched.block("kv")[0])
    if S % bq or T % bk or H % K:
        return None, f"untileable shape S={S} T={T} H={H} K={K}"
    return ({"block_q": bq, "block_k": bk, "buffering": sched.buffering,
             "est_step_cycles": sched.est_step_cycles,
             "vmem_bytes": sched.vmem_bytes,
             **_pipeline_fields(sched)}, "ok")


def _rmsnorm_schedule(key: OpKey):
    rows, d = key.shape
    return {"block_rows": _down_pow2(rows, 256)}, "ok"


def _int8_matmul_schedule(key: OpKey):
    M, Kd, N = key.shape
    sched = choose_matmul_blocks(M, N, Kd, dtype_bytes=1)
    bm = _down_pow2(M, sched.block("a")[0])
    bn = _down_pow2(N, sched.block("b")[1])
    bk = _down_pow2(Kd, sched.block("a")[1])
    if M % bm or N % bn or Kd % bk:
        return None, f"untileable shape M={M} N={N} K={Kd}"
    return ({"block_m": bm, "block_n": bn, "block_k": bk,
             "buffering": sched.buffering, **_pipeline_fields(sched)}, "ok")


def _ssd_schedule(key: OpKey):
    b, s, H, P, N = key.shape
    sched = choose_ssd_blocks(s, H, P, N)
    chunk = _down_pow2(s, sched.block("chunk")[0])
    if s % chunk:
        return None, f"untileable sequence s={s}"
    return ({"chunk": chunk, "buffering": sched.buffering,
             **_pipeline_fields(sched)}, "ok")


def _dtype_bytes(dtype: str) -> int:
    # same itemsize convention as _attention_schedule, so the recorded
    # schedule matches the one the pointcloud/ops wrapper re-derives
    try:
        return np.dtype(dtype).itemsize
    except TypeError:
        return 2 if dtype.endswith("16") else 4


def _fps_schedule(key: OpKey):
    B, N, S = key.shape
    if S > N:
        return None, f"more samples than points (S={S} > N={N})"
    db = _dtype_bytes(key.dtype)
    if fps_vmem_bytes(N, S, db) > TPU_VMEM_BUDGET:
        # FPS has no tiling to shrink — an oversized cloud takes the
        # reference, exactly as the pointcloud/ops wrapper does
        return None, f"point set exceeds VMEM (N={N})"
    sched = choose_fps_blocks(N, S, db)
    return ({"n_points": N, "n_samples": S, "buffering": sched.buffering,
             "vmem_bytes": sched.vmem_bytes,
             **_pipeline_fields(sched)}, "ok")


def _ball_schedule(key: OpKey):
    B, N, M, K = key.shape
    sched = choose_ball_blocks(M, N, K, _dtype_bytes(key.dtype))
    tiles = pcops.pc_tiles(M, N, sched, "x")
    if tiles is None:
        return None, f"untileable shape M={M} N={N} (pow2 tiles degrade)"
    return ({"block_m": tiles[0], "block_n": tiles[1],
             "buffering": sched.buffering,
             **_pipeline_fields(sched)}, "ok")


def _group_schedule(key: OpKey):
    B, N, M, K, C = key.shape
    sched = choose_group_blocks(M, N, K, C, _dtype_bytes(key.dtype))
    tiles = pcops.pc_tiles(M, N, sched, "f")
    if tiles is None:
        return None, f"untileable shape M={M} N={N} (pow2 tiles degrade)"
    return ({"block_m": tiles[0], "block_n": tiles[1],
             "buffering": sched.buffering,
             **_pipeline_fields(sched)}, "ok")


_SCHEDULERS = {
    "attention": _attention_schedule,
    "attention_decode": _attention_schedule,
    "attention_paged": _attention_schedule,
    "rmsnorm": _rmsnorm_schedule,
    "int8_matmul": _int8_matmul_schedule,
    "ssd_scan": _ssd_schedule,
    "fps": _fps_schedule,
    "ball_query": _ball_schedule,
    "group_aggregate": _group_schedule,
}


class Dispatcher:
    """Persistent in-process compile cache over the e-graph ISAX pipeline.

    ``lower`` is the only entry point the models call (at jit-trace time, so
    steady-state inference never pays a dispatch cost).  E-graph outcomes are
    memoized per trace kind — attention prefill/decode/paged share one
    saturation run — while schedules and impl decisions are per shape.
    """

    def __init__(self):
        self.records: dict[OpKey, CompileRecord] = {}
        self._outcomes: dict[str, MatchOutcome] = {}
        self.hits = 0
        self.misses = 0

    # -- e-graph compilation (per trace kind) ------------------------------

    def match_outcome(self, kind: str) -> MatchOutcome:
        """E-graph saturation + matching for one trace kind (memoized)."""
        out = self._outcomes.get(kind)
        if out is None:
            res = compile_program(trace_term(kind), isax_library(),
                                  case=f"dispatch/{kind}")
            s = res.stats
            out = MatchOutcome(tuple(dict.fromkeys(s.matched_isaxes)),
                               s.internal_rewrites, s.external_rewrites,
                               s.initial_enodes, s.saturated_enodes)
            self._outcomes[kind] = out
        return out

    # -- lowering decision (per key) ---------------------------------------

    def lower(self, key: OpKey) -> CompileRecord:
        """The compile-cache lookup: returns the (memoized) lowering
        decision for one (op, shape, dtype, backend) key."""
        rec = self.records.get(key)
        if rec is not None:
            self.hits += 1
            rec.hits += 1
            return rec
        self.misses += 1
        rec = self._decide(key)
        self.records[key] = rec
        return rec

    def _decide(self, key: OpKey) -> CompileRecord:
        outcome = self.match_outcome(trace_kind(key.op))
        target = TARGET_ISAX[key.op]
        matched = target is not None and target in outcome.matched

        def _rec(impl, kernel_fn=None, schedule=None, note=""):
            return CompileRecord(key=key, impl=impl, matched=outcome.matched,
                                 target=target, kernel_fn=kernel_fn,
                                 schedule=schedule, note=note,
                                 outcome=outcome)

        if key.backend in ("pallas", "pallas_interpret"):
            if not matched:
                return _rec("reference",
                            note="no ISAX matched; XLA reference")
            schedule, why = _SCHEDULERS[key.op](key)
            if schedule is None:
                return _rec("reference",
                            note=f"{target} matched but {why}; XLA reference")
            return _rec("isax", kernel_fn=_KERNELS[target],
                        schedule=schedule, note=f"extracted isax:{target}")
        if key.backend == "xla_chunked" and key.op.startswith("attention"):
            B, S = key.shape[0], key.shape[1]
            if S > 1:
                return _rec("chunked",
                            note="online-softmax chunked XLA lowering")
            return _rec("reference", note="single-row query; XLA reference")
        return _rec("reference", note=f"backend {key.backend}: XLA reference"
                    + ("" if not matched else f" ({target} matched)"))

    # -- introspection ------------------------------------------------------

    def stats(self) -> dict:
        """Aggregate match-rate / cache-hit-rate plus per-key rows (the
        BENCH_compile.json payload)."""
        recs = list(self.records.values())
        n = len(recs)
        matched = sum(1 for r in recs if r.target_matched)
        isax = sum(1 for r in recs if r.impl == "isax")
        pipelined = sum(1 for r in recs
                        if r.schedule and r.schedule.get("pipelined"))
        lookups = self.hits + self.misses
        return {
            "n_keys": n,
            "matched_keys": matched,
            "isax_keys": isax,
            "pipelined_keys": pipelined,
            "match_rate": matched / n if n else 0.0,
            "isax_rate": isax / n if n else 0.0,
            "cache_hits": self.hits,
            "cache_misses": self.misses,
            "hit_rate": self.hits / lookups if lookups else 0.0,
            "ops": [r.row() for r in recs],
        }


_DISPATCHER = Dispatcher()


def get_dispatcher() -> Dispatcher:
    """The process-wide compile cache (persistent across engines/models)."""
    return _DISPATCHER
