"""Generic compile-cache engine over the declarative ISAX/domain registry.

For each ``OpKey`` (op, shape, dtype, backend) the dispatcher runs the full
retargetable-compiler flow over the traced software program — equality
saturation (``core/rewrites``) interleaved with ISAX-guided external loop
transforms, then skeleton/component matching (``core/matching``) — and
decides whether to extract an ``isax:*`` kernel call (the spec's bound
Pallas entry point, with a schedule from the spec's ``kernel_synth``
scheduler) or fall back to the XLA reference.  Decisions live in a
persistent in-process compile cache, so the e-graph work is paid once per
trace spec and the schedule/tileability decision once per shape; later jit
traces of the same op hit the cache.

The engine is *registry-generic*: it imports no domain module, names no
op, and holds no scheduler/kernel tables.  Everything op-specific — trace
program, target ISAX, scheduler, kernel entry point, chunked-XLA policy —
comes from the ``repro.targets`` registry (``IsaxSpec``), so a new domain
plugs in by registration alone.  Kernel entry points are resolved at spec
registration, never lazily inside a forward function: a ``CompileRecord``
carries the bound callable.

Invariants:

* **Cache key** — the compile cache is keyed on the full
  ``OpKey(op, shape, dtype, backend)`` tuple and nothing else; two lookups
  with equal keys always return the *same* ``CompileRecord`` object, and
  any input property that should change the lowering (a new shape, a dtype
  switch, a different backend preference) must be part of the key.
* **E-graph amortization** — saturation/matching outcomes are memoized per
  *registry spec identity* (attention prefill/decode/paged share one spec
  and therefore one run; two domains can never alias a trace kind by
  picking the same kind string).  Schedules and impl decisions are per
  key.  ``lower`` is called at jit-trace time only, so steady-state
  inference never pays a dispatch cost.
* **Recorded schedules are the executed schedules** — the schedule dict in
  a ``CompileRecord`` (tiles, buffer depth, burst-pipeline go/no-go) uses
  the same ``core.kernel_synth`` entry points the kernel wrappers consult,
  so what ``BENCH_compile.json`` reports is what the kernel ran.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional

from repro.compile.trace import OpKey
from repro.core.offload import compile_program
from repro.targets import default_registry
from repro.targets.registry import IsaxSpec, TargetRegistry


@dataclasses.dataclass(frozen=True)
class MatchOutcome:
    """E-graph compilation result for one trace spec (shape-independent)."""

    matched: tuple[str, ...]
    internal_rewrites: int
    external_rewrites: int
    initial_enodes: int
    saturated_enodes: int


@dataclasses.dataclass
class CompileRecord:
    """One compile-cache entry: the match result and lowering decision for a
    single (op, shape, dtype, backend) tuple."""

    key: OpKey
    impl: str                      # 'isax' | 'chunked' | 'reference'
    matched: tuple[str, ...]       # every ISAX the e-graph pipeline matched
    target: Optional[str]          # the ISAX this op is expected to target
    kernel_fn: Optional[Callable]  # resolved entry point when impl == 'isax'
    schedule: Optional[dict]       # synthesis-chosen tiling when impl == 'isax'
    note: str                      # human-readable decision rationale
    outcome: MatchOutcome
    hits: int = 0

    @property
    def target_matched(self) -> bool:
        """True iff the e-graph pipeline matched this op's target ISAX."""
        return self.target is not None and self.target in self.matched

    def row(self) -> dict:
        """Flatten the record for the ``BENCH_compile.json`` artifact."""
        return {
            "op": self.key.op, "shape": list(self.key.shape),
            "dtype": self.key.dtype, "backend": self.key.backend,
            "impl": self.impl, "matched": list(self.matched),
            "target": self.target, "schedule": self.schedule,
            "note": self.note, "hits": self.hits,
            "internal_rewrites": self.outcome.internal_rewrites,
            "external_rewrites": self.outcome.external_rewrites,
            "saturated_enodes": self.outcome.saturated_enodes,
        }


class Dispatcher:
    """Persistent in-process compile cache over the e-graph ISAX pipeline.

    ``lower`` is the only entry point the models call (at jit-trace time, so
    steady-state inference never pays a dispatch cost).  E-graph outcomes
    are memoized per registry spec — attention prefill/decode/paged share
    one spec's saturation run — while schedules and impl decisions are per
    shape.  Pass ``registry=`` to bind a custom :class:`TargetRegistry`
    (e.g. an isolated registry carrying an experimental domain); the
    default is the global ``repro.targets`` registry.
    """

    def __init__(self, registry: Optional[TargetRegistry] = None):
        self.registry = registry if registry is not None else default_registry()
        self.records: dict[OpKey, CompileRecord] = {}
        #: spec identity → MatchOutcome; keyed on the IsaxSpec *object*
        #: (``eq=False``), never its kind string — two domains reusing a
        #: kind label get independent saturation runs by construction.
        self._outcomes: dict[IsaxSpec, MatchOutcome] = {}
        self.hits = 0
        self.misses = 0

    # -- e-graph compilation (per trace spec) ------------------------------

    def match_outcome(self, spec: IsaxSpec) -> MatchOutcome:
        """E-graph saturation + matching for one trace spec (memoized on
        the spec's identity)."""
        out = self._outcomes.get(spec)
        if out is None:
            res = compile_program(
                spec.trace_program(), self.registry.isaxes(),
                case=f"dispatch/{spec.domain}/{spec.trace_kind}")
            s = res.stats
            out = MatchOutcome(tuple(dict.fromkeys(s.matched_isaxes)),
                               s.internal_rewrites, s.external_rewrites,
                               s.initial_enodes, s.saturated_enodes)
            self._outcomes[spec] = out
        return out

    # -- lowering decision (per key) ---------------------------------------

    def lower(self, key: OpKey) -> CompileRecord:
        """The compile-cache lookup: returns the (memoized) lowering
        decision for one (op, shape, dtype, backend) key."""
        rec = self.records.get(key)
        if rec is not None:
            self.hits += 1
            rec.hits += 1
            return rec
        self.misses += 1
        rec = self._decide(key)
        self.records[key] = rec
        return rec

    def _decide(self, key: OpKey) -> CompileRecord:
        spec = self.registry.op_spec(key.op)  # ValueError on unknown op
        outcome = self.match_outcome(spec)
        target = spec.target
        matched = target is not None and target in outcome.matched

        def _rec(impl, kernel_fn=None, schedule=None, note=""):
            return CompileRecord(key=key, impl=impl, matched=outcome.matched,
                                 target=target, kernel_fn=kernel_fn,
                                 schedule=schedule, note=note,
                                 outcome=outcome)

        if key.backend in ("pallas", "pallas_interpret"):
            if not matched:
                return _rec("reference",
                            note="no ISAX matched; XLA reference")
            schedule, why = spec.scheduler(key)
            if schedule is None:
                return _rec("reference",
                            note=f"{target} matched but {why}; XLA reference")
            return _rec("isax", kernel_fn=spec.kernel,
                        schedule=schedule, note=f"extracted isax:{target}")
        if key.backend == "xla_chunked" and spec.chunked is not None:
            if key.shape[spec.chunked.axis] > 1:
                return _rec("chunked", note=spec.chunked.note)
            return _rec("reference", note=spec.chunked.fallback_note)
        return _rec("reference", note=f"backend {key.backend}: XLA reference"
                    + ("" if not matched else f" ({target} matched)"))

    # -- introspection ------------------------------------------------------

    def stats(self) -> dict:
        """Aggregate match-rate / cache-hit-rate plus per-key rows (the
        BENCH_compile.json payload)."""
        recs = list(self.records.values())
        n = len(recs)
        matched = sum(1 for r in recs if r.target_matched)
        isax = sum(1 for r in recs if r.impl == "isax")
        pipelined = sum(1 for r in recs
                        if r.schedule and r.schedule.get("pipelined"))
        lookups = self.hits + self.misses
        return {
            "n_keys": n,
            "matched_keys": matched,
            "isax_keys": isax,
            "pipelined_keys": pipelined,
            "match_rate": matched / n if n else 0.0,
            "isax_rate": isax / n if n else 0.0,
            "cache_hits": self.hits,
            "cache_misses": self.misses,
            "hit_rate": self.hits / lookups if lookups else 0.0,
            "ops": [r.row() for r in recs],
        }


_DISPATCHER: Optional[Dispatcher] = None


def get_dispatcher() -> Dispatcher:
    """The process-wide compile cache (persistent across engines/models),
    bound to the global ``repro.targets`` registry."""
    global _DISPATCHER
    if _DISPATCHER is None:
        _DISPATCHER = Dispatcher()
    return _DISPATCHER


def __getattr__(name: str):
    """Deprecation shims for the pre-registry module internals.

    ``_SCHEDULERS`` and ``_KERNELS`` were hand-maintained dicts scripts
    sometimes reached into; both are now derived views over the registry
    and will be removed after one release.
    """
    if name == "_SCHEDULERS":
        warnings.warn(
            "repro.compile.dispatch._SCHEDULERS is deprecated; schedulers "
            "live on repro.targets IsaxSpec entries "
            "(default_registry().op_spec(op).scheduler)",
            DeprecationWarning, stacklevel=2)
        reg = default_registry()
        return {op: reg.op_spec(op).scheduler for op in reg.ops()
                if reg.op_spec(op).scheduler is not None}
    if name == "_KERNELS":
        warnings.warn(
            "repro.compile.dispatch._KERNELS is deprecated; kernel entry "
            "points live on repro.targets IsaxSpec entries "
            "(default_registry().spec(name).kernel)",
            DeprecationWarning, stacklevel=2)
        return {s.name: s.kernel for s in default_registry().specs()
                if s.kernel is not None}
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
