"""``LoweringConfig``: the backend/dispatch handle threaded through models
and engines, replacing the old ``models.layers`` module-global impl flags.

Environment overrides (``REPRO_ATTENTION_IMPL``, falling back to
``REPRO_BACKEND``) are read in exactly one place — this constructor — and
only when no explicit backend is given.  Everything downstream (layers,
model families, serve engines, launchers) receives the object; nothing else
consults ``os.environ``.
"""

from __future__ import annotations

import os
from typing import Optional

from repro.compile.dispatch import CompileRecord, Dispatcher, get_dispatcher
from repro.compile.trace import OpKey
from repro.kernels import ref as kref
from repro.pointcloud import ref as pcref

VALID_BACKENDS = ("xla", "xla_chunked", "pallas", "pallas_interpret")

#: First env var set wins; read only by the LoweringConfig constructor.
_ENV_VARS = ("REPRO_ATTENTION_IMPL", "REPRO_BACKEND")


class LoweringConfig:
    """Per-model/engine lowering policy.

    backend:
      'xla'              — reference jnp lowering everywhere (default)
      'xla_chunked'      — online-softmax chunked attention in pure XLA
      'pallas'           — compiled Pallas ISAX kernels (TPU)
      'pallas_interpret' — Pallas kernel bodies in interpret mode (CPU tests)

    The backend states a *preference*; the dispatcher still decides per
    (op, shape, dtype) whether the e-graph pipeline matched an ISAX and
    whether the synthesis schedule is feasible, falling back to the XLA
    reference otherwise.
    """

    def __init__(self, backend: Optional[str] = None,
                 dispatcher: Optional[Dispatcher] = None):
        if backend is None:
            for name in _ENV_VARS:
                backend = os.environ.get(name)
                if backend:
                    break
            backend = backend or "xla"
        if backend not in VALID_BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"valid: {VALID_BACKENDS}")
        self.backend = backend
        self.interpret = backend == "pallas_interpret"
        self.dispatcher = dispatcher or get_dispatcher()

    def __repr__(self):
        return f"LoweringConfig(backend={self.backend!r})"

    def lower(self, op: str, shape, dtype) -> CompileRecord:
        """Compile-cache lookup for one op instance (called at trace time)."""
        return self.dispatcher.lower(
            OpKey(op, tuple(int(s) for s in shape), str(dtype), self.backend))

    # -- standalone op entry points (ops with no models/ host function) ----

    def int8_matmul(self, x, wq, scale):
        """Quantized GEMM through the dispatcher: x (M,K) float, wq (N,K)
        int8, scale (N,) → (M,N)."""
        M, K = x.shape
        N = wq.shape[0]
        rec = self.lower("int8_matmul", (M, K, N), x.dtype)
        if rec.impl == "isax":
            return rec.kernel_fn(x, wq, scale, interpret=self.interpret)
        return kref.int8_matmul_ref(x, wq, scale)

    # -- point-cloud vertical (fps → ball_query → group_aggregate) ---------

    def fps(self, xyz, n_samples: int):
        """Farthest-point sampling through the dispatcher: xyz (B,N,d) →
        sampled indices (B, n_samples) i32."""
        B, N, _ = xyz.shape
        rec = self.lower("fps", (B, N, n_samples), xyz.dtype)
        if rec.impl == "isax":
            return rec.kernel_fn(xyz, n_samples, interpret=self.interpret)
        return pcref.fps_ref(xyz, n_samples)

    def ball_query(self, xyz, centers, radius: float, k: int):
        """Ball-query grouping through the dispatcher: xyz (B,N,d),
        centers (B,M,d) → neighbor indices (B,M,k) i32."""
        B, N, _ = xyz.shape
        M = centers.shape[1]
        rec = self.lower("ball_query", (B, N, M, k), xyz.dtype)
        if rec.impl == "isax":
            return rec.kernel_fn(xyz, centers, radius, k,
                                 interpret=self.interpret)
        return pcref.ball_query_ref(xyz, centers, radius, k)

    def group_aggregate(self, features, idx):
        """Grouped feature aggregation through the dispatcher: features
        (B,N,C), idx (B,M,k) → max-pooled (B,M,C)."""
        B, N, C = features.shape
        M, k = idx.shape[1], idx.shape[2]
        rec = self.lower("group_aggregate", (B, N, M, k, C), features.dtype)
        if rec.impl == "isax":
            return rec.kernel_fn(features, idx, interpret=self.interpret)
        return pcref.group_aggregate_ref(features, idx)


# ---------------------------------------------------------------------------
# Process default (what model functions use when no LoweringConfig is
# threaded in — e.g. the trainer and the dry-run launcher).
# ---------------------------------------------------------------------------

_DEFAULT: Optional[LoweringConfig] = None


def default_lowering() -> LoweringConfig:
    """The process-default LoweringConfig (created lazily from the env)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = LoweringConfig()
    return _DEFAULT


def set_default_lowering(lowering: LoweringConfig) -> Optional[LoweringConfig]:
    """Install a new process-default; returns the prior one (for restore)."""
    global _DEFAULT
    prior = _DEFAULT
    _DEFAULT = lowering
    return prior


def set_default_backend(backend: str) -> str:
    """Launcher convenience: swap the default backend, returning the prior
    backend name.  Note jit caches traces — changing the default does not
    retrace already-compiled functions (same as the old global flag)."""
    prior = default_lowering().backend
    set_default_lowering(LoweringConfig(backend=backend))
    return prior


def get_default_backend() -> str:
    """Backend name of the process-default LoweringConfig."""
    return default_lowering().backend
