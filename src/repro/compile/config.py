"""``LoweringConfig``: the backend/dispatch handle threaded through models
and engines, replacing the old ``models.layers`` module-global impl flags.

Environment overrides (``REPRO_ATTENTION_IMPL``, falling back to
``REPRO_BACKEND``) are read in exactly one place — this constructor — and
only when no explicit backend is given.  Everything downstream (layers,
model families, serve engines, launchers) receives the object; nothing else
consults ``os.environ``.
"""

from __future__ import annotations

import os
from typing import Optional

from repro.compile.dispatch import CompileRecord, Dispatcher, get_dispatcher
from repro.compile.trace import OpKey
from repro.kernels import ref as kref
from repro.pointcloud import ref as pcref
from repro.targets.registry import TargetRegistry

VALID_BACKENDS = ("xla", "xla_chunked", "pallas", "pallas_interpret")

#: First env var set wins; read only by the LoweringConfig constructor.
_ENV_VARS = ("REPRO_ATTENTION_IMPL", "REPRO_BACKEND")


class LoweringConfig:
    """Per-model/engine lowering policy.

    backend:
      'xla'              — reference jnp lowering everywhere (default)
      'xla_chunked'      — online-softmax chunked attention in pure XLA
      'pallas'           — compiled Pallas ISAX kernels (TPU)
      'pallas_interpret' — Pallas kernel bodies in interpret mode (CPU tests)

    The backend states a *preference*; the dispatcher still decides per
    (op, shape, dtype) whether the e-graph pipeline matched an ISAX and
    whether the synthesis schedule is feasible, falling back to the XLA
    reference otherwise.
    """

    def __init__(self, backend: Optional[str] = None,
                 dispatcher: Optional[Dispatcher] = None):
        if backend is None:
            for name in _ENV_VARS:
                backend = os.environ.get(name)
                if backend:
                    break
            backend = backend or "xla"
        if backend not in VALID_BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"valid: {VALID_BACKENDS}")
        self.backend = backend
        self.interpret = backend == "pallas_interpret"
        self.dispatcher = dispatcher or get_dispatcher()

    @classmethod
    def from_registry(cls, backend: Optional[str] = None, *,
                      registry: Optional[TargetRegistry] = None,
                      dispatcher: Optional[Dispatcher] = None
                      ) -> "LoweringConfig":
        """Build a lowering policy over an ISAX/domain registry.

        The canonical constructor for engines, launchers, examples, and
        benchmarks: with no arguments it binds the global ``repro.targets``
        registry through the process-wide compile cache; pass ``registry=``
        to dispatch against an isolated :class:`TargetRegistry` (e.g. one
        carrying an experimental domain) with its own fresh cache, or
        ``dispatcher=`` to share a specific cache instance.
        """
        if dispatcher is None:
            dispatcher = (Dispatcher(registry) if registry is not None
                          else get_dispatcher())
        elif registry is not None and dispatcher.registry is not registry:
            raise ValueError("pass either registry= or dispatcher=, not "
                             "disagreeing both")
        return cls(backend=backend, dispatcher=dispatcher)

    @property
    def registry(self) -> TargetRegistry:
        """The ISAX/domain registry this policy dispatches against."""
        return self.dispatcher.registry

    def __repr__(self):
        return f"LoweringConfig(backend={self.backend!r})"

    def lower(self, op: str, shape, dtype) -> CompileRecord:
        """Compile-cache lookup for one op instance (called at trace time)."""
        return self.dispatcher.lower(
            OpKey(op, tuple(int(s) for s in shape), str(dtype), self.backend))

    # -- standalone op entry points (ops with no models/ host function) ----

    def int8_matmul(self, x, wq, scale):
        """Quantized GEMM through the dispatcher: x (M,K) float, wq (N,K)
        int8, scale (N,) → (M,N)."""
        M, K = x.shape
        N = wq.shape[0]
        rec = self.lower("int8_matmul", (M, K, N), x.dtype)
        if rec.impl == "isax":
            return rec.kernel_fn(x, wq, scale, interpret=self.interpret)
        return kref.int8_matmul_ref(x, wq, scale)

    # -- point-cloud vertical (fps → ball_query → group_aggregate) ---------

    def fps(self, xyz, n_samples: int):
        """Farthest-point sampling through the dispatcher: xyz (B,N,d) →
        sampled indices (B, n_samples) i32."""
        B, N, _ = xyz.shape
        rec = self.lower("fps", (B, N, n_samples), xyz.dtype)
        if rec.impl == "isax":
            return rec.kernel_fn(xyz, n_samples, interpret=self.interpret)
        return pcref.fps_ref(xyz, n_samples)

    def ball_query(self, xyz, centers, radius: float, k: int):
        """Ball-query grouping through the dispatcher: xyz (B,N,d),
        centers (B,M,d) → neighbor indices (B,M,k) i32."""
        B, N, _ = xyz.shape
        M = centers.shape[1]
        rec = self.lower("ball_query", (B, N, M, k), xyz.dtype)
        if rec.impl == "isax":
            return rec.kernel_fn(xyz, centers, radius, k,
                                 interpret=self.interpret)
        return pcref.ball_query_ref(xyz, centers, radius, k)

    def group_aggregate(self, features, idx):
        """Grouped feature aggregation through the dispatcher: features
        (B,N,C), idx (B,M,k) → max-pooled (B,M,C)."""
        B, N, C = features.shape
        M, k = idx.shape[1], idx.shape[2]
        rec = self.lower("group_aggregate", (B, N, M, k, C), features.dtype)
        if rec.impl == "isax":
            return rec.kernel_fn(features, idx, interpret=self.interpret)
        return pcref.group_aggregate_ref(features, idx)


# ---------------------------------------------------------------------------
# Process default (what model functions use when no LoweringConfig is
# threaded in — e.g. the trainer and the dry-run launcher).
# ---------------------------------------------------------------------------

_DEFAULT: Optional[LoweringConfig] = None


def default_lowering() -> LoweringConfig:
    """The process-default LoweringConfig (created lazily from the env,
    bound to the global ``repro.targets`` registry)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = LoweringConfig.from_registry()
    return _DEFAULT


def lower(op: str, *, shape, dtype, backend: Optional[str] = None
          ) -> CompileRecord:
    """Public one-shot lowering: compile-cache lookup for one op instance
    through the registry-backed dispatch pipeline.

    The top-level entry point of the retargetable lowering API:
    ``repro.compile.lower("attention", shape=(1, 128, 4, 2, 128, 64),
    dtype="float32", backend="pallas")``.  With ``backend=None`` the
    process-default policy (env override included) applies; an explicit
    ``backend`` reuses the default policy's dispatcher (and therefore its
    registry and compile cache), so repeated calls are O(dict lookup) and
    a custom default installed via ``set_default_lowering`` keeps working.
    """
    dflt = default_lowering()
    if backend is None:
        return dflt.lower(op, shape, dtype)
    return LoweringConfig(backend=backend,
                          dispatcher=dflt.dispatcher).lower(op, shape, dtype)


def set_default_lowering(lowering: LoweringConfig) -> Optional[LoweringConfig]:
    """Install a new process-default; returns the prior one (for restore)."""
    global _DEFAULT
    prior = _DEFAULT
    _DEFAULT = lowering
    return prior


def set_default_backend(backend: str) -> str:
    """Launcher convenience: swap the default backend, returning the prior
    backend name.  Note jit caches traces — changing the default does not
    retrace already-compiled functions (same as the old global flag)."""
    prior = default_lowering().backend
    set_default_lowering(LoweringConfig.from_registry(backend))
    return prior


def get_default_backend() -> str:
    """Backend name of the process-default LoweringConfig."""
    return default_lowering().backend
