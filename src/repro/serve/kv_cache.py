"""Block-paged KV cache for continuous-batching serving (vLLM-style).

The monolithic per-request ``(B, T, K, hd)`` cache of the static engine
wastes HBM proportional to ``max_len`` for every request regardless of its
actual length, and its batch dimension is welded to the request group, so
admitting a new request mid-decode would change jit shapes.  Here KV lives
in a shared pool of fixed-size pages:

    k_pages / v_pages : (L, n_pages, page_size, K, hd)

and each batch *slot* owns a row of a page table mapping logical page p →
physical page id.  The decode step gathers pages through the table, so the
jit'd shapes (pool, table, seq_lens) are constant no matter which requests
come and go — only the table/length *contents* change.

``PageAllocator`` is pure host-side bookkeeping (free list with double-free
and leak detection); ``PagedKVCache`` owns the device pools plus the table.

Invariants:

* **Page ownership** — every physical page is either on the allocator's
  free list or owned by exactly one slot (``_slot_pages``).  ``bind_slot``
  reserves a request's whole lifetime up front (prompt bucket + max new
  tokens), so decode can never fail mid-flight; ``release_slot`` is the
  only way pages return to the pool.
* **Free-list discipline** — ``free`` rejects double-frees and foreign
  pages; ``check_leaks`` asserts the pool is exactly full once no request
  is live (the continuous engine calls it after every workload).
* **Snapshot before transfer** — ``device_views`` copies the host-side
  ``page_table``/``seq_lens`` *before* handing them to ``jnp.asarray``:
  the host→device copy is asynchronous, and engines mutate those arrays
  immediately after dispatching a decode step.  Mutating the un-snapshotted
  array races the in-flight transfer and intermittently corrupts the
  step's lengths (the PR-2 race fix — keep the ``.copy()``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L


class PageAllocationError(RuntimeError):
    """Raised when the pool cannot satisfy an allocation."""


class PageAllocator:
    """Free-list allocator over ``n_pages`` physical pages.

    Guards the two classic lifetime bugs: freeing a page twice and leaking
    pages when a request retires.  ``check_leaks`` asserts the pool is
    exactly full again once no requests are live.
    """

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._allocated: set[int] = set()

    @property
    def n_free(self) -> int:
        """Number of pages currently on the free list."""
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        """True iff ``n`` pages can be allocated without failing."""
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Take ``n`` pages off the free list (all-or-nothing); raises
        ``PageAllocationError`` when the pool can't cover the request."""
        if n > len(self._free):
            raise PageAllocationError(
                f"requested {n} pages, only {len(self._free)} free "
                f"of {self.n_pages}")
        pages = [self._free.pop() for _ in range(n)]
        self._allocated.update(pages)
        return pages

    def free(self, pages: list[int]) -> None:
        """Return pages to the free list; raises ``PageAllocationError`` on
        a double-free or a page the allocator never handed out."""
        for p in pages:
            if p not in self._allocated:
                raise PageAllocationError(
                    f"double-free or foreign page: {p}")
            self._allocated.remove(p)
            self._free.append(p)

    def check_invariants(self) -> None:
        """Assert the free list and allocated set exactly partition the
        pool (no leak, no duplicate, no page in both states)."""
        assert len(self._free) + len(self._allocated) == self.n_pages, (
            f"page leak: {len(self._free)} free + "
            f"{len(self._allocated)} allocated != {self.n_pages}")
        assert len(set(self._free)) == len(self._free), "duplicate free page"
        assert not (set(self._free) & self._allocated), (
            "page simultaneously free and allocated")

    def check_leaks(self) -> None:
        """Assert the pool is exactly full again — call once no request is
        live (every retire path must have freed its pages)."""
        self.check_invariants()
        assert not self._allocated, (
            f"{len(self._allocated)} pages leaked: "
            f"{sorted(self._allocated)[:8]}…")


@dataclasses.dataclass
class PagedKVCache:
    """Device page pools + host page table for ``max_batch`` slots."""

    cfg: ModelConfig
    max_batch: int
    page_size: int
    n_pages: int
    max_len: int

    def __post_init__(self):
        cfg = self.cfg
        assert self.max_len % self.page_size == 0, (
            "max_len must be a page multiple")
        self.pages_per_seq = self.max_len // self.page_size
        cd = L.dtype_of(cfg.compute_dtype)
        shape = (cfg.n_layers, self.n_pages, self.page_size,
                 cfg.n_kv_heads, cfg.resolved_head_dim())
        self.k_pages = jnp.zeros(shape, cd)
        self.v_pages = jnp.zeros(shape, cd)
        self.allocator = PageAllocator(self.n_pages)
        # Host-side view; pushed to device each decode step (tiny int arrays).
        self.page_table = np.zeros((self.max_batch, self.pages_per_seq),
                                   np.int32)
        self.seq_lens = np.zeros((self.max_batch,), np.int32)
        self._slot_pages: dict[int, list[int]] = {}

    # -- lifetime ----------------------------------------------------------

    def pages_needed(self, total_tokens: int) -> int:
        """Pages required to hold ``total_tokens`` KV entries (ceil)."""
        return -(-total_tokens // self.page_size)

    def can_admit(self, total_tokens: int) -> bool:
        """True iff the pool can reserve a whole request lifetime now."""
        return self.allocator.can_alloc(self.pages_needed(total_tokens))

    def bind_slot(self, slot: int, total_tokens: int) -> list[int]:
        """Reserve pages covering the request's whole lifetime (prompt bucket
        + max new tokens) so decode can never fail mid-flight."""
        assert slot not in self._slot_pages, f"slot {slot} already bound"
        pages = self.allocator.alloc(self.pages_needed(total_tokens))
        self._slot_pages[slot] = pages
        self.page_table[slot] = 0
        self.page_table[slot, :len(pages)] = pages
        self.seq_lens[slot] = 0
        return pages

    def release_slot(self, slot: int) -> None:
        """Free a retired slot's pages and clear its table row — the only
        path by which pages return to the pool."""
        self.allocator.free(self._slot_pages.pop(slot))
        self.page_table[slot] = 0
        self.seq_lens[slot] = 0

    # -- data movement -----------------------------------------------------

    def write_prefill(self, slot: int, kv: dict, length: int) -> None:
        """Scatter a prefill KV stack (L, 1, S_pad, K, hd) into this slot's
        pages.  S_pad must be a page multiple (prompt bucketing guarantees
        it); padded positions are written too but stay masked until decode
        overwrites them."""
        k, v = kv["k"], kv["v"]
        s_pad = k.shape[2]
        assert s_pad % self.page_size == 0
        n = s_pad // self.page_size
        ids = self.page_table[slot, :n]
        lk = k.shape[0]
        shape = (lk, n, self.page_size) + k.shape[3:]
        self.k_pages = self.k_pages.at[:, ids].set(
            k[:, 0].reshape(shape).astype(self.k_pages.dtype))
        self.v_pages = self.v_pages.at[:, ids].set(
            v[:, 0].reshape(shape).astype(self.v_pages.dtype))
        self.seq_lens[slot] = length

    def device_views(self, active_slots: set[int]):
        """(page_table, seq_lens, active) device arrays for the decode step.

        The host arrays are snapshotted (``.copy()``) before the transfer:
        ``jnp.asarray`` enqueues an *async* host→device copy, and callers
        advance ``seq_lens`` immediately after dispatching the decode step —
        without the snapshot that mutation races the in-flight transfer and
        intermittently corrupts the step's lengths.
        """
        active = np.zeros((self.max_batch,), bool)
        for s in active_slots:
            active[s] = True
        return (jnp.asarray(self.page_table.copy()),
                jnp.asarray(self.seq_lens.copy()),
                jnp.asarray(active))
