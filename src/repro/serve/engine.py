"""Serving engines: the original static-batch ``ServeEngine`` (one prefill +
jit'd decode loop over a monolithic KV cache, TTFT/ITL measurement — the
paper's §6.5 LLM-inference metrics, optional int8 weights) and the
continuous-batching ``ContinuousEngine``:

    RequestQueue → Scheduler (slot admission/retirement)
                 → PagedKVCache (fixed-size pages, free-list allocator)
                 → jit-stable decode step (gathers pages via the page table)

New requests are admitted into in-flight decode batches the moment a slot
and enough pages free up; prompts are prefilled one at a time into bucketed
shapes (bounded recompiles) and their KV scattered into pages, so mixed
prompt/output lengths no longer waste decode steps on padding.
``StaticBatchEngine`` runs the same workload API with classic static
batching — the baseline the serve benchmark compares against.

The static decode step is the same function the dry-run lowers as
``serve_step``.

All engines obtain their attention/rmsnorm/matmul kernels through the
``repro.compile`` dispatcher: a ``LoweringConfig`` (constructor reads the
``REPRO_ATTENTION_IMPL`` env override; pass ``lowering=`` to pin a backend)
is threaded into the model, and the e-graph ISAX pipeline decides per
(op, shape, dtype, backend) whether prefill/decode run an extracted Pallas
kernel or the XLA reference.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compile import LoweringConfig
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.registry import Model, get_model
from repro.serve.kv_cache import PagedKVCache
from repro.serve.scheduler import (Request, RequestQueue, Scheduler,
                                   pick_bucket)


@dataclasses.dataclass
class ServeStats:
    """Latency/throughput stats for one static-batch generation."""

    ttft_s: float
    itl_s: float
    tokens: int
    tokens_per_s: float


def quantize_params_int8(params):
    """Per-tensor symmetric int8 quantization of every ≥2-D weight; returns
    (quantized tree with {'q','scale'} leaves, dequant function)."""

    def _quant(p):
        if p.ndim >= 2:
            scale = jnp.maximum(jnp.max(jnp.abs(p.astype(jnp.float32))),
                                1e-12) / 127.0
            q = jnp.clip(jnp.round(p.astype(jnp.float32) / scale),
                         -127, 127).astype(jnp.int8)
            return {"q": q, "scale": scale, "dtype": str(p.dtype)}
        return p

    def _is_weight(x):
        return isinstance(x, jax.Array)

    qtree = jax.tree.map(_quant, params, is_leaf=_is_weight)

    def _dequant(tree):
        def _deq(x):
            if isinstance(x, dict) and "q" in x:
                return (x["q"].astype(jnp.float32) * x["scale"]).astype(
                    L.dtype_of(x["dtype"]) if isinstance(x["dtype"], str)
                    else jnp.float32)
            return x
        return jax.tree.map(_deq, tree,
                            is_leaf=lambda x: isinstance(x, dict)
                            and "q" in x)

    return qtree, _dequant


def quantization_error(params, qtree, dequant) -> float:
    """Mean relative L1 error of the int8 round-trip over all weights."""
    deq = dequant(qtree)
    num = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(deq)))
    den = sum(float(jnp.sum(jnp.abs(a))) for a in jax.tree.leaves(params))
    return num / max(den, 1e-12)


class ServeEngine:
    """Single-batch prefill + decode engine over a monolithic KV cache —
    the TTFT/ITL measurement harness and the numerics reference the other
    engines are checked against."""

    def __init__(self, model_cfg: ModelConfig, params=None, *,
                 max_len: int = 512, quantize: bool = False, seed: int = 0,
                 lowering: Optional[LoweringConfig] = None):
        self.cfg = model_cfg
        # Kernel choice is a compile decision: the engine's prefill/decode
        # obtain attention/rmsnorm/matmul implementations from the
        # repro.compile dispatcher through this LoweringConfig (env override
        # REPRO_ATTENTION_IMPL is read by its constructor).
        self.lowering = (lowering if lowering is not None
                         else LoweringConfig.from_registry())
        self.model = get_model(model_cfg, lowering=self.lowering)
        self.max_len = max_len
        # (memory model: int8 at rest, dequantized once on load — wire/HBM
        # bytes halved)
        self.params = _init_params(self.model, params, quantize, seed)
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.max_len),
            static_argnums=())
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))

    def generate(self, batch: dict, n_tokens: int,
                 greedy: bool = True) -> tuple[np.ndarray, ServeStats]:
        """Prefill ``batch`` and greedily decode ``n_tokens`` tokens;
        returns ``(tokens (B, n_tokens), ServeStats)``."""
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, batch)
        logits.block_until_ready()
        ttft = time.perf_counter() - t0

        n_prefix = (self.cfg.n_prefix_tokens
                    if self.cfg.family == "vlm" else 0)
        pos = batch["tokens"].shape[1] + n_prefix
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [np.asarray(token)]
        t1 = time.perf_counter()
        for i in range(n_tokens - 1):
            logits, caches = self._decode(self.params, token, caches,
                                          jnp.int32(pos + i))
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(np.asarray(token))
        token.block_until_ready()
        t2 = time.perf_counter()
        itl = (t2 - t1) / max(n_tokens - 1, 1)
        stats = ServeStats(ttft_s=ttft, itl_s=itl, tokens=n_tokens,
                           tokens_per_s=n_tokens / (t2 - t0))
        return np.stack(out, axis=1), stats


# ---------------------------------------------------------------------------
# Workload-level serving (lists of Requests with arrival times)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkloadStats:
    """Aggregate latency/throughput over one served request workload."""

    n_requests: int
    total_tokens: int
    wall_s: float
    tokens_per_s: float
    mean_ttft_s: float
    mean_itl_s: float
    decode_steps: int


def _aggregate(requests: list[Request], wall_s: float,
               decode_steps: int) -> WorkloadStats:
    total = sum(len(r.out_tokens) for r in requests)
    ttfts = [r.ttft_s for r in requests if r.t_first_token is not None]
    itls = [r.itl_s for r in requests if len(r.out_tokens) > 1]
    return WorkloadStats(
        n_requests=len(requests), total_tokens=total, wall_s=wall_s,
        tokens_per_s=total / max(wall_s, 1e-9),
        mean_ttft_s=float(np.mean(ttfts)) if ttfts else 0.0,
        mean_itl_s=float(np.mean(itls)) if itls else 0.0,
        decode_steps=decode_steps)


DEFAULT_BUCKETS = (16, 32, 64)


def _init_params(model: Model, params, quantize: bool, seed: int):
    if params is None:
        params = model.init(jax.random.key(seed))
    if quantize:
        qtree, dequant = quantize_params_int8(params)
        params = dequant(qtree)
    return params


def _filter_buckets(buckets: tuple[int, ...], max_len: int) -> tuple[int, ...]:
    out = tuple(b for b in sorted(buckets) if b <= max_len)
    assert out, f"no prompt bucket in {buckets} fits max_len {max_len}"
    return out


class ContinuousEngine:
    """Continuous-batching server over a paged KV cache.

    ``max_batch`` decode slots share a pool of ``n_pages`` KV pages; the
    decode step's shapes are fixed at construction, so admissions and
    retirements never trigger recompilation.  Prefill compiles once per
    prompt bucket.  Arrival times are in decode steps (virtual time, see
    ``scheduler``); latencies are wall-clock.
    """

    def __init__(self, model_cfg: ModelConfig, params=None, *,
                 max_batch: int = 8, page_size: int = 16,
                 max_len: int = 128, n_pages: Optional[int] = None,
                 prompt_buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 quantize: bool = False, seed: int = 0,
                 lowering: Optional[LoweringConfig] = None):
        self.cfg = model_cfg
        self.lowering = (lowering if lowering is not None
                         else LoweringConfig.from_registry())
        self.model = get_model(model_cfg, lowering=self.lowering)
        if self.model.decode_paged is None:
            raise ValueError(
                f"family {model_cfg.family!r} has no paged decode path")
        self.params = _init_params(self.model, params, quantize, seed)
        self.max_len = max_len
        self.prompt_buckets = _filter_buckets(prompt_buckets, max_len)
        assert all(b % page_size == 0 for b in self.prompt_buckets), (
            "prompt buckets must be page multiples")
        if n_pages is None:
            n_pages = max_batch * (max_len // page_size)
        self.cache = PagedKVCache(model_cfg, max_batch=max_batch,
                                  page_size=page_size, n_pages=n_pages,
                                  max_len=max_len)
        self.scheduler = Scheduler(max_batch)
        self.queue = RequestQueue()
        self.step_count = 0
        self._next_tokens = np.zeros((max_batch,), np.int32)
        # jax.jit caches one executable per prompt-bucket shape.
        self._prefill = jax.jit(
            lambda p, b, length: self.model.prefill_at(p, b, length))
        # Decode state lives on device between steps; host re-uploads it only
        # when batch membership changes (admission/retirement), and argmax +
        # seq-len advance run inside the jit so steady-state decode is a
        # single dispatch + one small token fetch.
        self._device_state = None
        self._membership_dirty = True

        def _decode_fn(p, t, kp, vp, pt, sl, act):
            logits, kp, vp = self.model.decode_paged(p, t, kp, vp, pt, sl,
                                                     act)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, kp, vp, sl + act.astype(sl.dtype)

        self._decode = jax.jit(_decode_fn, donate_argnums=(2, 3))

    # -- internals ---------------------------------------------------------

    def _lifetime_tokens(self, req: Request, bucket: int) -> int:
        return max(bucket, req.prompt_len + req.max_new_tokens)

    def _admit(self, req: Request) -> None:
        slot = self.scheduler.bind(req)
        bucket = pick_bucket(req.prompt_len, self.prompt_buckets)
        self.cache.bind_slot(slot, self._lifetime_tokens(req, bucket))
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :req.prompt_len] = req.prompt
        logits, kv = self._prefill(
            self.params, {"tokens": jnp.asarray(tokens)},
            jnp.int32(req.prompt_len))
        self.cache.write_prefill(slot, kv, req.prompt_len)
        first = int(jnp.argmax(logits[0]))
        now = time.perf_counter()
        req.out_tokens.append(first)
        req.t_first_token = now
        if len(req.out_tokens) >= req.max_new_tokens:
            req.t_done = now
        self._next_tokens[slot] = first
        self._membership_dirty = True

    def _retire_finished(self) -> None:
        for slot in self.scheduler.finished_slots():
            self.scheduler.retire(slot)
            self.cache.release_slot(slot)
            self._membership_dirty = True

    def step(self) -> bool:
        """One scheduler iteration: retire → admit (+prefill) → decode.
        Returns True iff a decode step actually ran."""
        now = time.perf_counter()
        self._retire_finished()
        # Stamp eligibility (for TTFT) on everything that has arrived.
        for r in self.queue:
            if r.arrival_step <= self.step_count and r.t_eligible is None:
                r.t_eligible = now
        while self.scheduler.has_capacity():
            head = self.queue.head()
            if head is None or head.arrival_step > self.step_count:
                break
            bucket = pick_bucket(head.prompt_len, self.prompt_buckets)
            if not self.cache.can_admit(self._lifetime_tokens(head, bucket)):
                break  # FIFO head-of-line: wait for pages to free
            req = self.queue.pop_eligible(self.step_count)
            if req.t_eligible is None:
                req.t_eligible = now
            self._admit(req)
        # A request whose budget was met at prefill (max_new_tokens == 1)
        # must not ride through a decode dispatch.
        self._retire_finished()
        active = self.scheduler.active_slots
        if active:
            if self._membership_dirty or self._device_state is None:
                pt, sl, act = self.cache.device_views(active)
                # snapshot: _next_tokens is mutated after dispatch and the
                # host→device copy is async (see device_views)
                self._device_state = (jnp.asarray(self._next_tokens.copy()),
                                      pt, sl, act)
                self._membership_dirty = False
            tokens_d, pt, sl, act = self._device_state
            tokens_d, self.cache.k_pages, self.cache.v_pages, sl = \
                self._decode(self.params, tokens_d, self.cache.k_pages,
                             self.cache.v_pages, pt, sl, act)
            self._device_state = (tokens_d, pt, sl, act)
            nxt = np.asarray(tokens_d)
            now = time.perf_counter()
            for slot in active:
                req = self.scheduler.slots[slot]
                self.cache.seq_lens[slot] += 1
                if len(req.out_tokens) < req.max_new_tokens:
                    req.out_tokens.append(int(nxt[slot]))
                    if len(req.out_tokens) >= req.max_new_tokens:
                        req.t_done = now
                self._next_tokens[slot] = nxt[slot]
        self.step_count += 1
        return bool(active)

    # -- public API --------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Queue a request, rejecting one that could never be admitted
        (lifetime exceeding ``max_len`` or the whole page pool)."""
        bucket = pick_bucket(req.prompt_len, self.prompt_buckets)
        lifetime = self._lifetime_tokens(req, bucket)
        if lifetime > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + "
                f"max_new {req.max_new_tokens} exceeds max_len {self.max_len}")
        if self.cache.pages_needed(lifetime) > self.cache.n_pages:
            raise ValueError(
                f"request {req.rid}: needs "
                f"{self.cache.pages_needed(lifetime)} pages but the pool "
                f"only has {self.cache.n_pages} — it could never be "
                f"admitted")
        self.queue.push(req)

    def run(self, requests: list[Request]) -> WorkloadStats:
        """Serve a whole workload to completion; asserts no page leaked."""
        for r in requests:
            self.submit(r)
        # Arrival steps are relative to workload start; a reused engine must
        # not carry a prior run's step count into the gating.
        self.step_count = 0
        t0 = time.perf_counter()
        decode_steps = 0
        while self.queue or self.scheduler.has_active():
            decode_steps += int(self.step())
        wall = time.perf_counter() - t0
        self.cache.allocator.check_leaks()
        return _aggregate(requests, wall, decode_steps)


class StaticBatchEngine:
    """Classic static batching over the same workload API: groups of up to
    ``batch`` eligible requests are padded to a common prompt bucket,
    prefilled together, and decoded for max(output length) steps — the
    whole group holds its slots until the longest member finishes.  Output
    *tokens* for shorter-prompt members are computed at padded positions
    (standard static-batch behavior); this engine is the throughput/latency
    baseline, the numerics reference is ``ServeEngine``."""

    def __init__(self, model_cfg: ModelConfig, params=None, *,
                 batch: int = 8, max_len: int = 128,
                 prompt_buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 quantize: bool = False, seed: int = 0,
                 lowering: Optional[LoweringConfig] = None):
        self.cfg = model_cfg
        self.lowering = (lowering if lowering is not None
                         else LoweringConfig.from_registry())
        self.model = get_model(model_cfg, lowering=self.lowering)
        self.params = _init_params(self.model, params, quantize, seed)
        self.batch = batch
        self.max_len = max_len
        self.prompt_buckets = _filter_buckets(prompt_buckets, max_len)
        # jax.jit caches one executable per prompt-bucket shape.
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.max_len))

        def _decode_fn(p, t, c, pos):
            logits, c = self.model.decode_step(p, t, c, pos)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), c

        self._decode = jax.jit(_decode_fn, donate_argnums=(2,))

    def run(self, requests: list[Request]) -> WorkloadStats:
        """Serve a workload in static groups (the baseline scheduler)."""
        queue = RequestQueue()
        for r in requests:
            queue.push(r)
        t0 = time.perf_counter()
        step_count = 0
        decode_steps = 0
        while queue:
            now = time.perf_counter()
            for r in queue:
                if r.arrival_step <= step_count and r.t_eligible is None:
                    r.t_eligible = now
            group = []
            while len(group) < self.batch:
                req = queue.pop_eligible(step_count)
                if req is None:
                    break
                if req.t_eligible is None:
                    req.t_eligible = now
                group.append(req)
            if not group:
                step_count += 1  # idle: wait for the next arrival
                continue
            bucket = pick_bucket(max(r.prompt_len for r in group),
                                 self.prompt_buckets)
            n_gen = max(r.max_new_tokens for r in group)
            # Decode writes KV at positions bucket..bucket+n_gen-2 (the last
            # generated token is never fed back).
            if bucket + n_gen - 1 > self.max_len:
                raise ValueError(
                    f"group needs positions up to {bucket + n_gen - 2} but "
                    f"the KV cache holds max_len={self.max_len}; decode "
                    f"writes past it would silently clamp")
            tokens = np.zeros((self.batch, bucket), np.int32)
            for i, r in enumerate(group):
                tokens[i, :r.prompt_len] = r.prompt
            logits, caches = self._prefill(
                self.params, {"tokens": jnp.asarray(tokens)})
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            host = np.asarray(token)  # sync before the TTFT stamp
            now = time.perf_counter()
            for i, r in enumerate(group):
                r.out_tokens.append(int(host[i]))
                r.t_first_token = now
                if r.max_new_tokens == 1:
                    r.t_done = now
            n_steps = n_gen - 1
            for j in range(n_steps):
                token, caches = self._decode(self.params, token, caches,
                                             jnp.int32(bucket + j))
                host = np.asarray(token)
                now = time.perf_counter()
                for i, r in enumerate(group):
                    if len(r.out_tokens) < r.max_new_tokens:
                        r.out_tokens.append(int(host[i]))
                        if len(r.out_tokens) >= r.max_new_tokens:
                            r.t_done = now
                # Requests whose virtual arrival falls inside this group's
                # decode start waiting *now*; stamping here (not after the
                # group drains) charges that head-of-line wait to their TTFT.
                for r in queue:
                    if (r.arrival_step <= step_count + j + 1
                            and r.t_eligible is None):
                        r.t_eligible = now
            step_count += n_steps
            decode_steps += n_steps
        wall = time.perf_counter() - t0
        return _aggregate(requests, wall, decode_steps)
