"""Serving engine: batched prefill + jit'd decode loop with a static KV cache,
TTFT/ITL measurement (the paper's §6.5 LLM-inference metrics), and optional
int8 weight quantization (the paper's 8-bit Llama deployment).

The decode step is the same function the dry-run lowers as ``serve_step``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.registry import Model, get_model


@dataclasses.dataclass
class ServeStats:
    ttft_s: float
    itl_s: float
    tokens: int
    tokens_per_s: float


def quantize_params_int8(params):
    """Per-tensor symmetric int8 quantization of every ≥2-D weight; returns
    (quantized tree with {'q','scale'} leaves, dequant function)."""

    def quant(p):
        if p.ndim >= 2:
            scale = jnp.maximum(jnp.max(jnp.abs(p.astype(jnp.float32))),
                                1e-12) / 127.0
            q = jnp.clip(jnp.round(p.astype(jnp.float32) / scale),
                         -127, 127).astype(jnp.int8)
            return {"q": q, "scale": scale, "dtype": str(p.dtype)}
        return p

    def is_weight(x):
        return isinstance(x, jax.Array)

    qtree = jax.tree.map(quant, params, is_leaf=is_weight)

    def dequant(tree):
        def deq(x):
            if isinstance(x, dict) and "q" in x:
                return (x["q"].astype(jnp.float32) * x["scale"]).astype(
                    L.dtype_of(x["dtype"]) if isinstance(x["dtype"], str)
                    else jnp.float32)
            return x
        return jax.tree.map(deq, tree,
                            is_leaf=lambda x: isinstance(x, dict)
                            and "q" in x)

    return qtree, dequant


def quantization_error(params, qtree, dequant) -> float:
    deq = dequant(qtree)
    num = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(deq)))
    den = sum(float(jnp.sum(jnp.abs(a))) for a in jax.tree.leaves(params))
    return num / max(den, 1e-12)


class ServeEngine:
    def __init__(self, model_cfg: ModelConfig, params=None, *,
                 max_len: int = 512, quantize: bool = False, seed: int = 0):
        self.cfg = model_cfg
        self.model = get_model(model_cfg)
        self.max_len = max_len
        if params is None:
            params = self.model.init(jax.random.key(seed))
        if quantize:
            qtree, dequant = quantize_params_int8(params)
            params = dequant(qtree)  # dequantized-once weights (memory model:
            # int8 at rest, dequant on load — wire/HBM bytes halved)
        self.params = params
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.max_len),
            static_argnums=())
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))

    def generate(self, batch: dict, n_tokens: int,
                 greedy: bool = True) -> tuple[np.ndarray, ServeStats]:
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, batch)
        logits.block_until_ready()
        ttft = time.perf_counter() - t0

        n_prefix = (self.cfg.n_prefix_tokens
                    if self.cfg.family == "vlm" else 0)
        pos = batch["tokens"].shape[1] + n_prefix
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [np.asarray(token)]
        t1 = time.perf_counter()
        for i in range(n_tokens - 1):
            logits, caches = self._decode(self.params, token, caches,
                                          jnp.int32(pos + i))
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(np.asarray(token))
        token.block_until_ready()
        t2 = time.perf_counter()
        itl = (t2 - t1) / max(n_tokens - 1, 1)
        stats = ServeStats(ttft_s=ttft, itl_s=itl, tokens=n_tokens,
                           tokens_per_s=n_tokens / (t2 - t0))
        return np.stack(out, axis=1), stats
