"""Request queue + continuous-batching scheduler.

The scheduler owns the mapping *batch slot → request*.  Each engine step it
(1) retires slots whose request hit its token budget, freeing their pages,
and (2) admits queued requests into free slots whenever the page pool can
cover the request's whole lifetime — so a late-arriving short request rides
along with in-flight long ones instead of waiting for the batch to drain
(the decode batch shape never changes; see ``kv_cache.PagedKVCache``).

Arrival times are expressed in *decode steps* (virtual time): request i is
eligible once the engine has executed ``arrival_step`` steps.  That keeps
workloads deterministic across hosts of very different speeds while latency
metrics (TTFT/ITL) are still measured in wall-clock seconds.

Invariants:

* Every in-flight request is bound to exactly one slot, and every slot id
  is either in ``Scheduler.slots`` or on the free list — never both.
  ``bind`` is only legal when ``has_capacity()``; ``retire`` is the only
  way a slot returns to the free list.
* Admission is FIFO past the queue head only (``pop_eligible``): a request
  can never be overtaken, so no request starves behind the head-of-line
  page wait.
* The scheduler never touches KV pages itself — page ownership lives in
  ``kv_cache.PagedKVCache``; the engine must bind/release cache pages in
  lock-step with ``bind``/``retire`` (see ``engine.ContinuousEngine``).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request: prompt, token budget, and latency stamps
    (``t_*`` fields are filled in by the serving engine)."""

    rid: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int
    arrival_step: int = 0
    # Filled in by the engine:
    out_tokens: list = dataclasses.field(default_factory=list)
    t_eligible: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        """Number of prompt tokens."""
        return int(self.prompt.shape[0])

    @property
    def ttft_s(self) -> float:
        """Time to first token: eligibility → first generated token."""
        return self.t_first_token - self.t_eligible

    @property
    def itl_s(self) -> float:
        """Mean inter-token latency over the generated tokens."""
        n = len(self.out_tokens)
        if n <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / (n - 1)


class RequestQueue:
    """FIFO of pending requests with virtual-time arrival gating."""

    def __init__(self):
        self._q: collections.deque[Request] = collections.deque()

    def push(self, req: Request) -> None:
        """Append a request to the tail of the queue."""
        self._q.append(req)

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def __iter__(self):
        return iter(self._q)

    def head(self) -> Optional[Request]:
        """The next request to be admitted (None when empty)."""
        return self._q[0] if self._q else None

    def pop_eligible(self, step: int) -> Optional[Request]:
        """Pop the head iff it has arrived by ``step`` (FIFO — no reordering
        past the head, so no request starves)."""
        if self._q and self._q[0].arrival_step <= step:
            return self._q.popleft()
        return None

    def head_arrival(self) -> Optional[int]:
        """Arrival step of the queue head (None when empty)."""
        return self._q[0].arrival_step if self._q else None


class Scheduler:
    """Slot manager for continuous batching over ``max_batch`` slots."""

    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.slots: dict[int, Request] = {}      # slot -> in-flight request
        self._free_slots = list(range(max_batch - 1, -1, -1))

    @property
    def active_slots(self) -> set[int]:
        """Slot ids currently bound to in-flight requests."""
        return set(self.slots)

    def has_capacity(self) -> bool:
        """True iff at least one decode slot is free."""
        return bool(self._free_slots)

    def has_active(self) -> bool:
        """True iff any request is still in flight."""
        return bool(self.slots)

    def bind(self, req: Request) -> int:
        """Bind a request to a free slot; returns the slot id.  Only legal
        when ``has_capacity()`` — the engine checks before admitting."""
        slot = self._free_slots.pop()
        self.slots[slot] = req
        return slot

    def finished_slots(self) -> list[int]:
        """Slots whose request has produced its full token budget."""
        return [s for s, r in self.slots.items()
                if len(r.out_tokens) >= r.max_new_tokens]

    def retire(self, slot: int) -> Request:
        """Unbind a slot and return it to the free list; the caller must
        release the slot's KV pages in the same scheduler iteration."""
        req = self.slots.pop(slot)
        self._free_slots.append(slot)
        return req


def pick_bucket(prompt_len: int, buckets: tuple[int, ...]) -> int:
    """Smallest prefill bucket covering the prompt (bounds jit recompiles
    to ``len(buckets)`` prefill variants)."""
    for b in buckets:
        if prompt_len <= b:
            return b
    raise ValueError(f"prompt of {prompt_len} tokens exceeds the largest "
                     f"prefill bucket {buckets[-1]}")


def make_poisson_workload(n_requests: int, *, rate: float, vocab: int,
                          prompt_lens: tuple[int, ...] = (8, 16, 24, 32),
                          out_lens: tuple[int, ...] = (4, 8, 16, 48),
                          seed: int = 0) -> list[Request]:
    """Mixed-length workload with Poisson arrivals in step-space: inter-
    arrival gaps ~ Exp(rate) decode steps, prompt/output lengths sampled
    uniformly from the given grids.  Deterministic under ``seed`` so the
    static and continuous engines see the identical request stream."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate)
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, vocab, rng.choice(prompt_lens),
                                dtype=np.int32),
            max_new_tokens=int(rng.choice(out_lens)),
            arrival_step=int(t),
        ))
    return reqs
