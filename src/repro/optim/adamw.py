"""AdamW with global-norm clipping and optional int8 error-feedback gradient
compression (the distributed-optimization trick: 4× less gradient traffic on
the DP reduction path, with the quantization error carried forward so the
update is unbiased in the long run).

State dtypes are configurable so the 480B MoE fits (bf16 moments)."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"      # 'bfloat16' for the big MoEs
    compress_grads: bool = False      # int8 error-feedback compression


def init_state(params, cfg: AdamWConfig):
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }
    if cfg.compress_grads:
        state["err"] = jax.tree.map(zeros, params)
    return state


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def compress_int8(g, err):
    """Error-feedback int8 quantization: returns (dequantized g, new err).

    q = round(clip((g + err)/s)) with per-tensor scale s; the residual
    (g + err − deq(q)) feeds back into the next step.  On hardware the int8
    payload is what crosses the DP all-reduce (4× traffic cut); here the
    dequantized value continues through the update so numerics are identical
    to the wire version.
    """
    gf = g.astype(jnp.float32) + err.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, (gf - deq).astype(err.dtype)


def apply_updates(params, grads, state, cfg: AdamWConfig,
                  lr_scale: jnp.ndarray | float = 1.0):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    new_err = state.get("err")
    if cfg.compress_grads:
        pairs = jax.tree.map(compress_int8, grads, state["err"])
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"step": step, "m": new_m, "v": new_v}
    if cfg.compress_grads:
        new_state["err"] = new_err
    return new_params, new_state, {"grad_norm": gnorm}
