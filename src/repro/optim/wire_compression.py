"""Wire-level int8 gradient synchronization (shard_map).

``compress_int8`` in adamw.py models error-feedback quantization numerically,
but under pjit the gradient all-reduce is inserted by autodiff in fp32 — the
wire still carries 4 bytes/element.  This module provides the real thing for
data-parallel training: a shard_map train step whose gradient reduction is

    1. error-feedback int8 quantization (per-tensor scale, pmax'd),
    2. reduce-scatter via all_to_all of the int8 payload,
    3. local fp32 summation of the received shards,
    4. re-quantized int8 all_gather of the reduced shard.

Wire bytes per chip ≈ 2·S/4 vs fp32 ring all-reduce's 2·S — a 4× cut, at the
cost of one extra quantization of the *reduced* gradient (also carried in the
error-feedback state, so the bias is corrected over steps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamWConfig, apply_updates


def _flatten_grads(grads):
    leaves, treedef = jax.tree.flatten(grads)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    return flat, (treedef, [l.shape for l in leaves], sizes)


def _unflatten_grads(flat, meta):
    treedef, shapes, sizes = meta
    out, off = [], 0
    for shp, sz in zip(shapes, sizes):
        out.append(flat[off:off + sz].reshape(shp))
        off += sz
    return jax.tree.unflatten(treedef, out)


def int8_wire_allreduce(flat: jnp.ndarray, err: jnp.ndarray,
                        axis_names) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mean-reduce ``flat`` (1-D, f32, same length on every shard) across
    ``axis_names`` with int8 wire payload.  Returns (mean_grad, new_err)."""
    n = jax.lax.psum(1, axis_names)
    gf = flat + err
    scale1 = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_names) / 127.0
    scale1 = jnp.maximum(scale1, 1e-12)
    q1 = jnp.clip(jnp.round(gf / scale1), -127, 127).astype(jnp.int8)
    new_err = gf - q1.astype(jnp.float32) * scale1

    pad = (-q1.shape[0]) % n
    q1p = jnp.pad(q1, (0, pad))
    chunk = q1p.shape[0] // n
    # reduce-scatter: all_to_all int8 chunks, sum locally in f32
    parts = q1p.reshape(n, chunk)
    recv = jax.lax.all_to_all(parts, axis_names, 0, 0, tiled=True)
    local_sum = jnp.sum(recv.reshape(n, chunk).astype(jnp.float32), axis=0)
    local_mean = local_sum * (scale1 / n)
    # re-quantize the reduced shard and all_gather it (int8 wire again)
    scale2 = jax.lax.pmax(jnp.max(jnp.abs(local_mean)), axis_names) / 127.0
    scale2 = jnp.maximum(scale2, 1e-12)
    q2 = jnp.clip(jnp.round(local_mean / scale2), -127, 127).astype(jnp.int8)
    gathered = jax.lax.all_gather(q2, axis_names, tiled=True)
    mean = gathered.astype(jnp.float32) * scale2
    return mean[:flat.shape[0]], new_err


def make_int8_wire_train_step(model, opt_cfg: AdamWConfig, mesh,
                              dp_axes: tuple[str, ...]):
    """Data-parallel (replicated-params) train step with int8 gradient wire.

    in/out specs: params/opt replicated, batch sharded over ``dp_axes`` —
    build with batch leading dim divisible by the DP size.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def step(params, opt_state, err_flat, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        loss = jax.lax.pmean(loss, dp_axes)
        flat, meta = _flatten_grads(grads)
        mean_flat, new_err = int8_wire_allreduce(flat, err_flat, dp_axes)
        grads = _unflatten_grads(mean_flat, meta)
        new_params, new_opt, metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, new_err, metrics

    pspec = P()
    bspec = P(dp_axes)
    return shard_map(
        step, mesh=mesh,
        in_specs=(pspec, pspec, pspec, bspec),
        out_specs=(pspec, pspec, pspec, pspec),
        check_rep=False)


def init_err_state(params) -> jnp.ndarray:
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    return jnp.zeros((n,), jnp.float32)
