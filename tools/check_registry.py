"""Registry lint for CI: every registered ISAX must be benchable and tested.

Asserts, for every dispatchable ISAX spec in the global registry
(``isax`` set and at least one dispatch op):

* it resolves end to end (kernel entry point, scheduler, trace program,
  evaluator — via ``IsaxSpec.validate``),
* its declared bridging rewrites exist in ``core/rewrites.internal_rules``,
* it appears in ``benchmarks/bench_compile_stats.py``'s sweep (by spec
  name or by one of its ops), so ``BENCH_compile.json`` tracks it,
* it has at least one parity test under ``tests/`` mentioning it.

Run: ``python tools/check_registry.py`` (exit 1 with the violations).
"""

from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))


def main() -> None:
    from repro.core.rewrites import internal_rules
    from repro.targets import default_registry

    reg = default_registry()
    bench_src = (ROOT / "benchmarks" / "bench_compile_stats.py").read_text()
    test_srcs = {p.name: p.read_text()
                 for p in (ROOT / "tests").glob("test_*.py")}
    rule_names = {r.name for r in internal_rules()}

    errors: list[str] = []
    for spec in reg.specs():
        try:
            spec.validate()
        except ValueError as e:
            errors.append(f"{spec.name}: {e}")
            continue
        missing_rules = set(spec.rewrites) - rule_names
        if missing_rules:
            errors.append(f"{spec.name}: declares unknown bridging "
                          f"rewrites {sorted(missing_rules)}")
        if spec.isax is None or not spec.ops:
            continue  # negative controls / library-only specs
        mentions = (spec.name,) + spec.ops
        if not any(m in bench_src for m in mentions):
            errors.append(
                f"{spec.name}: not covered by bench_compile_stats' sweep "
                f"(none of {mentions} appear) — BENCH_compile.json would "
                f"not track it")
        tested_in = [fn for fn, src in test_srcs.items()
                     if any(m in src for m in mentions)]
        if not tested_in:
            errors.append(f"{spec.name}: no parity test under tests/ "
                          f"mentions {mentions}")

    if errors:
        print("registry lint FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        raise SystemExit(1)
    n = sum(1 for s in reg.specs() if s.isax is not None and s.ops)
    print(f"registry lint OK: {n} dispatchable ISAXes across "
          f"{len(reg.domains())} domains, all benched and tested")


if __name__ == "__main__":
    main()
