"""Generate the op → ISAX coverage table from the ``repro.targets`` registry.

The table in ``docs/ARCHITECTURE.md`` is *generated*, not hand-written, so
docs can no longer drift from code: every dispatch op, its target ISAX, the
bound kernel entry points (baseline and burst-pipelined), and the bridging
rewrites come straight from the registered ``IsaxSpec`` entries.

Usage:
    python tools/gen_isax_table.py                  # print the table
    python tools/gen_isax_table.py --write PATH...  # update marker blocks
    python tools/gen_isax_table.py --check PATH...  # CI: fail on drift
"""

from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

BEGIN = "<!-- BEGIN GENERATED: op-isax-table (tools/gen_isax_table.py) -->"
END = "<!-- END GENERATED: op-isax-table -->"


def _entry_point(fn) -> str:
    if fn is None:
        return "—"
    mod = fn.__module__.removeprefix("repro.")
    return f"`{mod}.{fn.__qualname__}`"


def render_table() -> str:
    """The markdown table, one row per registered dispatch op."""
    from repro.targets import default_registry
    reg = default_registry()
    rows = [
        "| op (dispatch key) | domain | ISAX matched | kernel entry point "
        "| burst-pipelined variant | bridging rewrites | notes |",
        "|---|---|---|---|---|---|---|",
    ]
    for op in reg.ops():
        spec = reg.op_spec(op)
        target = f"`{spec.target}`" if spec.target else "— (negative ctrl)"
        rewrites = ", ".join(f"`{r}`" for r in spec.rewrites) or "—"
        rows.append(
            f"| `{op}` | {spec.domain} | {target} "
            f"| {_entry_point(spec.kernel)} "
            f"| {_entry_point(spec.kernel_pipelined)} "
            f"| {rewrites} | {spec.note_for(op)} |")
    lib = ", ".join(f"`{s.name}`" for s in reg.specs()
                    if s.isax is not None and not s.ops)
    footer = (f"\nLibrary-only ISAXes (matchable, no dispatch key yet): "
              f"{lib or '—'}.\n")
    return "\n".join(rows) + "\n" + footer


def _splice(text: str, table: str, path: str) -> str:
    try:
        head, rest = text.split(BEGIN, 1)
        _, tail = rest.split(END, 1)
    except ValueError:
        raise SystemExit(f"{path}: marker block "
                         f"'{BEGIN}' … '{END}' not found") from None
    return f"{head}{BEGIN}\n{table}{END}{tail}"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", help="markdown files with the "
                                             "generated-table marker block")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--write", action="store_true",
                      help="rewrite the marker blocks in place")
    mode.add_argument("--check", action="store_true",
                      help="exit 1 if any marker block is stale")
    args = ap.parse_args()

    table = render_table()
    if not args.paths:
        print(table, end="")
        return
    stale = []
    for p in args.paths:
        text = pathlib.Path(p).read_text()
        new = _splice(text, table, p)
        if args.write:
            pathlib.Path(p).write_text(new)
            print(f"updated {p}")
        elif new != text:
            stale.append(p)
    if args.check and stale:
        raise SystemExit(
            f"generated op→ISAX table is stale in: {stale} — run "
            f"'python tools/gen_isax_table.py --write {' '.join(stale)}'")
    if args.check:
        print(f"op→ISAX table up to date in {args.paths}")


if __name__ == "__main__":
    main()
