#!/usr/bin/env python
"""Extract and smoke-execute the shell code blocks of README.md / docs/*.md.

Docs drift when nobody runs them; this script keeps every documented command
honest by executing the fenced ```bash blocks line by line on CI (the docs
job).  Rules:

* Only ``` ```bash ``` fences are executed; other languages are ignored.
* Blank lines and pure-comment lines are skipped.
* Lines matching a skip pattern are not run here because another CI job
  already covers them (`pip install`, the tier-1 `pytest` gate) — they are
  still printed so the skip is visible in the log.
* A line ending with ``# docs-ci: skip`` is never executed (for commands
  that need hardware or wall-clock the docs job can't afford).
* Everything runs from the repo root with BENCH_SMOKE=1 so benchmark
  invocations stay small.

Usage: python tools/run_doc_snippets.py README.md docs/ARCHITECTURE.md
Exits non-zero on the first failing command.
"""

from __future__ import annotations

import pathlib
import re
import subprocess
import sys

_SKIP = (
    re.compile(r"^pip\s+install"),            # the install step of each CI job
    re.compile(r"python\s+-m\s+pytest"),      # the tier-1 gate (test job)
    re.compile(r"python\s+-m\s+benchmarks\.run"),  # the test job's dedicated
                                                   # smoke-benchmark steps
)
_SKIP_MARK = "# docs-ci: skip"
_FENCE = re.compile(r"^```(\w*)\s*$")


def shell_blocks(text: str) -> list[str]:
    """Return the lines of every ```bash fenced block, in order."""
    lines, lang = [], None
    for raw in text.splitlines():
        m = _FENCE.match(raw.strip())
        if m:
            lang = m.group(1) if lang is None else None
            continue
        if lang == "bash":
            lines.append(raw.rstrip())
    return lines


def run_file(path: pathlib.Path, root: pathlib.Path) -> int:
    """Execute one document's bash lines; returns the number run."""
    n_run = 0
    for line in shell_blocks(path.read_text()):
        cmd = line.strip()
        if not cmd or cmd.startswith("#"):
            continue
        if cmd.endswith(_SKIP_MARK):
            print(f"[skip-marked] {cmd}")
            continue
        if any(p.search(cmd) for p in _SKIP):
            print(f"[covered-elsewhere] {cmd}")
            continue
        print(f"[run] {cmd}", flush=True)
        res = subprocess.run(["bash", "-c", cmd], cwd=root)
        if res.returncode != 0:
            print(f"FAILED ({res.returncode}): {cmd}  [{path}]",
                  file=sys.stderr)
            raise SystemExit(1)
        n_run += 1
    return n_run


def main() -> None:
    """Run every document named on the command line."""
    root = pathlib.Path(__file__).resolve().parent.parent
    docs = [pathlib.Path(a) for a in sys.argv[1:]] or [root / "README.md"]
    total = 0
    for doc in docs:
        doc = doc if doc.is_absolute() else root / doc
        if not doc.exists():
            print(f"FAILED: no such doc {doc}", file=sys.stderr)
            raise SystemExit(1)
        total += run_file(doc, root)
    print(f"doc snippets OK ({total} commands across {len(docs)} docs)")
    if total == 0:
        print("FAILED: no commands executed — are the fences ```bash?",
              file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
