"""End-to-end behaviour: the paper's full pipeline on a real (reduced) model —
describe a layer in the mini-IR, e-graph-compile it against the ISAX library,
execute the offloaded program through the Pallas datapaths, and train/serve
the corresponding JAX model.  Plus the hardware-side pipeline on TPU
interface instances."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.core import aquas_ir as ir
from repro.core.expr import arr, const, for_, var
from repro.core.interface_model import tpu_interfaces
from repro.core.offload import compile_program, evaluate
from repro.targets import isax_library
from repro.core.synthesis import synthesize
from repro.kernels.ops import register_kernel_intrinsics

register_kernel_intrinsics()


def test_end_to_end_attention_offload_and_execution():
    """A hand-written (syntactically divergent) attention loop is offloaded
    to the flash-attention ISAX and produces identical output through the
    interpret-mode Pallas kernel."""
    i = var("i")
    q = ("load", arr("Q"), i)
    s = ("/", ("exp", ("matvec", arr("K"), ("*", var("scale"), q))),
         ("rowsum", ("exp", ("matvec", arr("K"), ("*", var("scale"), q)))))
    sw = for_("i", const(0), var("n_q"), const(1),
              ("store", arr("P"), i, s),
              ("store", arr("O"), i,
               ("matvec", ("transpose", arr("V")), ("load", arr("P"), i))))
    res = compile_program(sw, isax_library(), case="e2e-attn")
    assert "flash_attention" in res.stats.matched_isaxes

    nq, nk, d = 8, 16, 32

    def env():
        r = np.random.default_rng(0)
        return dict(Q=r.normal(size=(nq, d)), K=r.normal(size=(nk, d)),
                    V=r.normal(size=(nk, d)), scale=d ** -0.5, n_q=nq,
                    P=np.zeros((nq, nk)), O=np.zeros((nq, d)))

    e0, e1 = env(), env()
    evaluate(sw, e0)
    evaluate(res.program, e1)
    np.testing.assert_allclose(e0["O"], e1["O"], atol=1e-5)


def test_end_to_end_tpu_synthesis_schedule():
    """The §4.3 pipeline on TPU interface instances produces an async DMA
    schedule whose cycles beat the naive single-path schedule."""
    from repro.core.interface_model import sequence_latency
    itfcs = tpu_interfaces()
    ops = [
        ir.FuncOp("transfer", "weights", 8 * 1024 * 1024, ir.Space.GLOBAL,
                  ir.Space.SCRATCHPAD, "load", ir.CacheHint.COLD),
        ir.FuncOp("transfer", "activations", 2 * 1024 * 1024,
                  ir.Space.GLOBAL, ir.Space.SCRATCHPAD, "load",
                  ir.CacheHint.WARM),
        ir.FuncOp("transfer", "out", 2 * 1024 * 1024, ir.Space.REG,
                  ir.Space.GLOBAL, "store", ir.CacheHint.COLD),
    ]
    prog = ir.FunctionalProgram("gemm_staging", ops, {})
    t = synthesize(prog, itfcs)
    assert t.total_cycles > 0
    # naive: everything over the slow ici path
    ici = itfcs["ici_link"]
    naive = sequence_latency(
        ici, ici.decompose(12 * 1024 * 1024), "load")
    assert t.total_cycles < naive


def test_end_to_end_train_then_serve(tmp_path):
    """Train the paper's llama110m (reduced) a few steps, checkpoint, reload
    into the serve engine, generate with int8 quantization."""
    from repro.optim.adamw import AdamWConfig
    from repro.serve.engine import ServeEngine
    from repro.train import checkpoint as ckpt
    from repro.train.trainer import TrainConfig, Trainer

    cfg = reduced(get_config("llama110m"))
    tc = TrainConfig(batch=4, seq=32, ckpt_dir=str(tmp_path), ckpt_every=4,
                     total_steps=8, optimizer=AdamWConfig(lr=1e-3))
    tr = Trainer(cfg, tc)
    last = tr.train(8)
    assert np.isfinite(last["loss"])
    tree, manifest = ckpt.load(str(tmp_path))
    assert manifest["step"] == 8
    params = jax.tree.map(
        lambda r, x: jnp.asarray(x, r.dtype), tr.params, tree["params"])
    eng = ServeEngine(cfg, params=params, max_len=48, quantize=True)
    toks, stats = eng.generate({"tokens": jnp.ones((2, 8), jnp.int32)}, 5)
    assert toks.shape == (2, 5)
    assert (toks >= 0).all() and (toks < cfg.vocab).all()


def test_offload_stats_reported_like_table3():
    """Compilation statistics have the Table-3 shape for the bench harness."""
    lib = isax_library()
    res = compile_program(lib[1].term, lib, case="stats-check")
    row = res.stats.row()
    assert row.count(",") == 5
    assert "int8_matvec" in row
