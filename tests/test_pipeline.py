"""Pipeline parallelism (GPipe over a mesh axis): numeric validation against
the sequential oracle.  shard_map needs multiple devices, so the check runs
in a subprocess with forced host devices (the only test allowed to do so —
the flag must never leak into this process)."""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.sharding.pipeline import gpipe, reference_forward

mesh = jax.make_mesh((2, 4), ("data", "model"))
S, L, D = 4, 8, 16          # 4 stages x 2 layers
n_micro, mb, seq = 6, 4, 8

rng = np.random.default_rng(0)
params = {"w": jnp.asarray(rng.normal(size=(L, D, D)) * 0.3, jnp.float32),
          "b": jnp.asarray(rng.normal(size=(L, D)) * 0.1, jnp.float32)}
x = jnp.asarray(rng.normal(size=(n_micro, mb, seq, D)), jnp.float32)

def stage_fn(p, x):
    def body(h, lp):
        return jnp.tanh(h @ lp[0] + lp[1]), None
    h, _ = jax.lax.scan(body, x, (p["w"], p["b"]))
    return h

pipelined = gpipe(stage_fn, mesh, stage_axis="model", data_axes=("data",))
with mesh:
    got = jax.jit(pipelined)(params, x)
want = reference_forward(stage_fn, params, x, n_stages=4)
err = float(jnp.abs(got - want).max())
assert err < 1e-5, err

# differentiability: grad of a scalar loss through the pipeline
def loss(p):
    return jnp.sum(jax.jit(pipelined)(p, x) ** 2)
with mesh:
    g = jax.grad(loss)(params)
def loss_ref(p):
    return jnp.sum(reference_forward(stage_fn, p, x, 4) ** 2)
g_ref = jax.grad(loss_ref)(params)
gerr = max(float(jnp.abs(a - b).max())
           for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)))
assert gerr < 1e-3, gerr
print(f"PIPELINE_OK fwd_err={err:.2e} grad_err={gerr:.2e}")
"""


def test_gpipe_matches_sequential_and_is_differentiable():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "PIPELINE_OK" in out.stdout, (out.stdout[-2000:],
                                         out.stderr[-2000:])
