"""Continuous-batching serve path: paged-KV numerics vs the static cache,
scheduler admission behavior, and page-allocator lifetime invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.models.registry import get_model
from repro.serve.engine import ContinuousEngine, StaticBatchEngine
from repro.serve.kv_cache import (PageAllocationError, PageAllocator,
                                  PagedKVCache)
from repro.serve.scheduler import (Request, RequestQueue, Scheduler,
                                   make_poisson_workload, pick_bucket)


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("llama110m"))


@pytest.fixture(scope="module")
def model_and_params(cfg):
    model = get_model(cfg)
    return model, model.init(jax.random.key(0))


# ---------------------------------------------------------------------------
# (a) paged-cache decode ≡ static-cache reference
# ---------------------------------------------------------------------------

class TestPagedNumerics:
    def test_paged_matches_static_decode(self, cfg, model_and_params):
        model, params = model_and_params
        B, PL, GEN, MAXLEN, PS = 4, 16, 6, 64, 16
        prompts = np.asarray(jax.random.randint(
            jax.random.key(1), (B, PL), 0, cfg.vocab), np.int32)

        logits, caches = model.prefill(
            params, {"tokens": jnp.asarray(prompts)}, MAXLEN)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        static_logits = [np.asarray(logits)]
        for i in range(GEN - 1):
            logits, caches = model.decode_step(params, tok, caches,
                                               jnp.int32(PL + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            static_logits.append(np.asarray(logits))

        cache = PagedKVCache(cfg, max_batch=B, page_size=PS,
                             n_pages=B * MAXLEN // PS, max_len=MAXLEN)
        toks = np.zeros((B,), np.int32)
        first = []
        for b in range(B):
            cache.bind_slot(b, PL + GEN)
            lg, kv = model.prefill_at(
                params, {"tokens": jnp.asarray(prompts[b:b + 1])},
                jnp.int32(PL))
            cache.write_prefill(b, kv, PL)
            first.append(np.asarray(lg[0]))
            toks[b] = int(jnp.argmax(lg[0]))
        paged_logits = [np.stack(first)]
        for _ in range(GEN - 1):
            pt, sl, act = cache.device_views(set(range(B)))
            lg, cache.k_pages, cache.v_pages = model.decode_paged(
                params, jnp.asarray(toks), cache.k_pages, cache.v_pages,
                pt, sl, act)
            cache.seq_lens[:] += 1
            toks = np.asarray(jnp.argmax(lg, -1), np.int32)
            paged_logits.append(np.asarray(lg))

        for step, (a, b) in enumerate(zip(static_logits, paged_logits)):
            np.testing.assert_allclose(
                a, b, atol=1e-5, rtol=0,
                err_msg=f"paged/static divergence at decode step {step}")

    def test_prefill_at_padded_prompt_exact(self, cfg, model_and_params):
        """Right-padding a prompt to a bucket must not change the logits at
        the true last position (causality)."""
        model, params = model_and_params
        PL, BUCKET = 11, 16
        prompt = np.asarray(jax.random.randint(
            jax.random.key(2), (1, PL), 0, cfg.vocab), np.int32)
        ref, _ = model.prefill(params, {"tokens": jnp.asarray(prompt)}, None)
        padded = np.zeros((1, BUCKET), np.int32)
        padded[0, :PL] = prompt
        got, _ = model.prefill_at(params, {"tokens": jnp.asarray(padded)},
                                  jnp.int32(PL))
        np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                                   atol=1e-5, rtol=0)


# ---------------------------------------------------------------------------
# (b) scheduler admits late arrivals into in-flight batches
# ---------------------------------------------------------------------------

class TestScheduler:
    def test_late_request_admitted_and_completes(self, cfg):
        eng = ContinuousEngine(cfg, max_batch=2, page_size=16, max_len=64,
                               prompt_buckets=(16,), seed=0)
        rng = np.random.default_rng(0)
        early = [Request(rid=i,
                         prompt=rng.integers(0, cfg.vocab, 8, dtype=np.int32),
                         max_new_tokens=12, arrival_step=0)
                 for i in range(2)]
        late = Request(rid=2,
                       prompt=rng.integers(0, cfg.vocab, 8, dtype=np.int32),
                       max_new_tokens=3, arrival_step=4)
        stats = eng.run(early + [late])
        for r in early + [late]:
            assert len(r.out_tokens) == r.max_new_tokens, r.rid
            assert r.t_first_token is not None and r.t_done is not None
        # the late request rode along with the in-flight batch: total decode
        # steps stay well below a drain-then-restart schedule
        assert stats.decode_steps < 12 + 3
        eng.cache.allocator.check_leaks()

    def test_queue_fifo_and_arrival_gating(self):
        q = RequestQueue()
        a = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=1,
                    arrival_step=5)
        q.push(a)
        assert q.pop_eligible(step=4) is None
        assert q.pop_eligible(step=5) is a

    def test_slot_reuse(self):
        s = Scheduler(max_batch=2)
        r = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=1)
        slot = s.bind(r)
        r.out_tokens.append(1)
        assert s.finished_slots() == [slot]
        assert s.retire(slot) is r
        assert s.has_capacity()

    def test_pick_bucket(self):
        assert pick_bucket(8, (16, 32)) == 16
        assert pick_bucket(17, (16, 32)) == 32
        with pytest.raises(ValueError):
            pick_bucket(64, (16, 32))


# ---------------------------------------------------------------------------
# (c) page allocator: no double-free, no leaks, full bench-style run
# ---------------------------------------------------------------------------

class TestPageAllocator:
    def test_alloc_free_roundtrip(self):
        a = PageAllocator(8)
        pages = a.alloc(5)
        assert len(set(pages)) == 5 and a.n_free == 3
        a.free(pages)
        a.check_leaks()

    def test_exhaustion_raises(self):
        a = PageAllocator(4)
        a.alloc(4)
        assert not a.can_alloc(1)
        with pytest.raises(PageAllocationError):
            a.alloc(1)

    def test_double_free_raises(self):
        a = PageAllocator(4)
        pages = a.alloc(2)
        a.free(pages)
        with pytest.raises(PageAllocationError):
            a.free(pages)
        with pytest.raises(PageAllocationError):
            a.free([99])

    def test_no_leak_across_bench_run(self, cfg):
        """A full mixed-length Poisson run (the bench scenario, smaller)
        returns every page to the pool and never trips the allocator's
        invariants mid-flight."""
        eng = ContinuousEngine(cfg, max_batch=4, page_size=16, max_len=128,
                               prompt_buckets=(16, 32), seed=0)
        reqs = make_poisson_workload(10, rate=2.0, vocab=cfg.vocab, seed=3)
        for r in reqs:
            eng.submit(r)
        while eng.queue or eng.scheduler.has_active():
            eng.step()
            eng.cache.allocator.check_invariants()
        eng.cache.allocator.check_leaks()
        assert eng.cache.allocator.n_free == eng.cache.allocator.n_pages
        for r in reqs:
            assert len(r.out_tokens) == r.max_new_tokens

    def test_oversized_request_rejected(self, cfg):
        eng = ContinuousEngine(cfg, max_batch=2, page_size=16, max_len=64,
                               prompt_buckets=(16,), seed=0)
        big = Request(rid=0, prompt=np.zeros(16, np.int32),
                      max_new_tokens=64)
        with pytest.raises(ValueError):
            eng.submit(big)

    def test_single_token_request_never_decodes(self, cfg):
        """max_new_tokens == 1 is satisfied by prefill alone; it must retire
        before the decode dispatch, not ride through one."""
        eng = ContinuousEngine(cfg, max_batch=2, page_size=16, max_len=64,
                               prompt_buckets=(16,), seed=0)
        r = Request(rid=0, prompt=np.zeros(8, np.int32), max_new_tokens=1)
        stats = eng.run([r])
        assert r.out_tokens and len(r.out_tokens) == 1
        assert stats.decode_steps == 0
        eng.cache.allocator.check_leaks()

    def test_pool_smaller_than_request_rejected(self, cfg):
        """A request that could never be admitted must be rejected at
        submit time, not spin run() forever waiting for pages."""
        eng = ContinuousEngine(cfg, max_batch=2, page_size=16, max_len=128,
                               n_pages=4, prompt_buckets=(16,), seed=0)
        big = Request(rid=0, prompt=np.zeros(16, np.int32),
                      max_new_tokens=80)  # 6 pages > 4-page pool
        with pytest.raises(ValueError):
            eng.submit(big)


# ---------------------------------------------------------------------------
# engines end-to-end on the same workload
# ---------------------------------------------------------------------------

class TestWorkloadEngines:
    def test_static_and_continuous_complete_same_workload(self, cfg):
        mk = lambda: make_poisson_workload(6, rate=2.0, vocab=cfg.vocab,
                                           prompt_lens=(8, 16),
                                           out_lens=(2, 4, 6), seed=1)
        for eng in (StaticBatchEngine(cfg, batch=2, max_len=64,
                                      prompt_buckets=(16,), seed=0),
                    ContinuousEngine(cfg, max_batch=2, page_size=16,
                                     max_len=64, prompt_buckets=(16,),
                                     seed=0)):
            reqs = mk()
            stats = eng.run(reqs)
            assert stats.total_tokens == sum(r.max_new_tokens for r in reqs)
            assert stats.tokens_per_s > 0
            assert all(r.t_done is not None for r in reqs)


def test_serve_cfg_smoke_matches_family_guard():
    ssm = reduced(get_config("mamba2-2.7b"))
    with pytest.raises(ValueError):
        ContinuousEngine(ssm, max_batch=2, page_size=16, max_len=64)
