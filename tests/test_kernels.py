"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("B,S,H,K,T,hd", [
    (1, 128, 1, 1, 128, 64),
    (2, 256, 4, 2, 256, 64),     # GQA 2:1
    (1, 256, 8, 1, 512, 128),    # MQA, cross lengths
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mask_kind", ["causal", "full"])
def test_flash_attention_sweep(B, S, H, K, T, hd, dtype, mask_kind):
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, T, K, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, T, K, hd)), dtype)
    if mask_kind == "causal":
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)[None]
    else:
        mask = jnp.ones((1, S, T), bool)
    out = flash_attention(q, k, v, jnp.broadcast_to(mask, (1, S, T)),
                          sm_scale=hd ** -0.5, block_q=128, block_k=128,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, mask, sm_scale=hd ** -0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_fully_masked_rows():
    """Rows with no valid keys must produce zeros, not NaNs."""
    B, S, H, hd = 1, 128, 1, 64
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, 1, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, 1, hd)), jnp.float32)
    mask = jnp.zeros((1, S, S), bool).at[:, :, :8].set(True).at[:, :8, :].set(False)
    out = flash_attention(q, k, v, mask, sm_scale=1.0, interpret=True)
    assert not bool(jnp.any(jnp.isnan(out)))
    np.testing.assert_allclose(np.asarray(out[0, :8]), 0.0, atol=1e-6)


@pytest.mark.parametrize("M,N,K", [(128, 128, 128), (256, 384, 512),
                                   (128, 256, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int8_matmul_sweep(M, N, K, dtype):
    x = jnp.asarray(RNG.normal(size=(M, K)), dtype)
    wq = jnp.asarray(RNG.integers(-127, 127, size=(N, K)), jnp.int8)
    scale = jnp.asarray(RNG.uniform(0.001, 0.02, size=(N,)), jnp.float32)
    out = int8_matmul(x, wq, scale, block_m=128, block_n=128, block_k=128,
                      interpret=True)
    want = ref.int8_matmul_ref(x, wq, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=0.5 if dtype == jnp.bfloat16 else 1e-2,
                               rtol=2e-2)


@pytest.mark.parametrize("BT,H,S,P,N,chunk", [
    (1, 1, 128, 8, 16, 64),
    (2, 3, 256, 16, 32, 128),
    (1, 2, 512, 64, 128, 256),   # production-ish state size
])
def test_ssd_scan_sweep(BT, H, S, P, N, chunk):
    x = jnp.asarray(RNG.normal(size=(BT, H, S, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 0.9, size=(BT, H, S)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 1.5, size=(H,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(BT, S, N)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(BT, S, N)), jnp.float32)
    out = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("R,d", [(256, 128), (512, 1024), (128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(R, d, dtype):
    x = jnp.asarray(RNG.normal(size=(R, d)), dtype)
    g = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
    out = rmsnorm(x, g, interpret=True)
    want = ref.rmsnorm_ref(x, g)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,K,T,hd", [
    (1, 128, 4, 2, 256, 64),
    (2, 128, 8, 1, 128, 128),
])
def test_flash_attention_int8kv(B, S, H, K, T, hd):
    """int8-KV flash kernel (the §6.5 quantized-attention ISAX): exact vs the
    dequantized oracle; bounded quantization error vs the fp oracle."""
    from repro.kernels.flash_attention import flash_attention_int8kv
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    kf = RNG.normal(size=(B, T, K, hd)).astype(np.float32)
    vf = RNG.normal(size=(B, T, K, hd)).astype(np.float32)
    ks = np.abs(kf).max(axis=(0, 1, 3)) / 127.0
    vs = np.abs(vf).max(axis=(0, 1, 3)) / 127.0
    k8 = jnp.asarray(np.clip(np.round(kf / ks[None, None, :, None]),
                             -127, 127), jnp.int8)
    v8 = jnp.asarray(np.clip(np.round(vf / vs[None, None, :, None]),
                             -127, 127), jnp.int8)
    mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)[None]
    out = flash_attention_int8kv(q, k8, v8, jnp.asarray(ks), jnp.asarray(vs),
                                 mask, sm_scale=hd ** -0.5, interpret=True)
    kd = jnp.asarray(k8, jnp.float32) * ks[None, None, :, None]
    vd = jnp.asarray(v8, jnp.float32) * vs[None, None, :, None]
    want = ref.flash_attention_ref(q, kd, vd, mask, sm_scale=hd ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=1e-4)
    want_fp = ref.flash_attention_ref(q, jnp.asarray(kf), jnp.asarray(vf),
                                      mask, sm_scale=hd ** -0.5)
    assert float(jnp.abs(out - want_fp).max()) < 0.1  # int8 quant noise


def test_ops_wrappers_choose_synthesized_blocks():
    """ops.* derive tile sizes from the interface-aware synthesis flow and
    fall back to the oracle for untileable shapes."""
    q = jnp.asarray(RNG.normal(size=(1, 96, 2, 64)), jnp.float32)  # 96: odd
    k = jnp.asarray(RNG.normal(size=(1, 96, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 96, 2, 64)), jnp.float32)
    mask = jnp.ones((1, 96, 96), bool)
    out = ops.flash_attention_gqa(q, k, v, mask, sm_scale=0.125,
                                  interpret=True)
    want = ref.flash_attention_ref(q, k, v, mask, sm_scale=0.125)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_layers_pallas_interpret_path_matches_xla():
    """models.layers attention with a pallas_interpret LoweringConfig == the
    xla-reference lowering (kernel choice through the compile dispatcher)."""
    from repro.compile import Dispatcher, LoweringConfig
    from repro.models import layers as L
    from repro.configs.registry import get_config
    from repro.configs.base import reduced
    cfg = reduced(get_config("granite-3-8b"))
    key = jax.random.key(0)
    p = L.init_attention(cfg, key)
    x = jnp.asarray(RNG.normal(size=(2, 128, cfg.d_model)), jnp.float32)
    mask = L.make_mask("causal", 128)
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    disp = Dispatcher()
    want, _ = L.attention(p, x, cfg, mask, pos,
                          lowering=LoweringConfig("xla", disp))
    got, _ = L.attention(p, x, cfg, mask, pos,
                         lowering=LoweringConfig("pallas_interpret", disp))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)
