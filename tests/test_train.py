"""Training substrate: optimizer, checkpoint/restart, fault tolerance, data
pipeline determinism, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

if os.environ.get("CI", "").lower() not in ("", "0", "false"):
    # CI must run the training-substrate properties, never skip them (the
    # workflow installs the dev extra; see tests/test_egraph.py).
    import hypothesis  # noqa: F401
else:
    pytest.importorskip(
        "hypothesis", reason="install the dev extra: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.optim.adamw import (AdamWConfig, apply_updates, compress_int8,
                               init_state)
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (FailureInjector, StragglerMonitor,
                                         run_with_restarts)
from repro.train.trainer import TrainConfig, Trainer

CFG = reduced(get_config("llama110m"))


class TestOptimizer:
    def test_adamw_decreases_loss(self):
        from repro.models.registry import get_model
        model = get_model(CFG)
        params = model.init(jax.random.key(0))
        opt_cfg = AdamWConfig(lr=1e-2)
        state = init_state(params, opt_cfg)
        pipe = TokenPipeline(CFG, 4, 32)
        batch = jax.tree.map(jnp.asarray, pipe.get_batch(0))
        loss0 = float(model.loss(params, batch))
        step = jax.jit(lambda p, s, b: apply_updates(
            p, jax.grad(model.loss)(p, b), s, opt_cfg))
        for _ in range(8):
            params, state, _ = step(params, state, batch)
        assert float(model.loss(params, batch)) < loss0

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_compression_error_feedback_bounded(self, seed):
        """|deq − (g+err)| ≤ scale/2: quantization error stays bounded and is
        carried forward, so compression is unbiased over time (property)."""
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(size=(32,)) * rng.uniform(0.01, 10))
        err = jnp.zeros_like(g)
        deq, new_err = compress_int8(g, err)
        scale = float(jnp.max(jnp.abs(g))) / 127.0
        assert float(jnp.max(jnp.abs(deq - g))) <= scale / 2 + 1e-9
        np.testing.assert_allclose(np.asarray(deq + new_err),
                                   np.asarray(g), atol=1e-6)

    def test_grad_clipping(self):
        p = {"w": jnp.ones((4,))}
        g = {"w": jnp.full((4,), 100.0)}
        cfg = AdamWConfig(clip_norm=1.0, lr=0.0, weight_decay=0.0)
        s = init_state(p, cfg)
        _, _, m = apply_updates(p, g, s, cfg)
        assert float(m["grad_norm"]) == pytest.approx(200.0)


class TestCheckpoint:
    def test_save_load_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(5, dtype=jnp.float32),
                "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
        ckpt.save(str(tmp_path), 7, tree)
        loaded, manifest = ckpt.load(str(tmp_path), verify=True)
        assert manifest["step"] == 7
        np.testing.assert_array_equal(np.asarray(loaded["a"]),
                                      np.arange(5, dtype=np.float32))
        assert loaded["b"]["c"].dtype == np.dtype("bfloat16") or \
            loaded["b"]["c"].dtype.name == "bfloat16"

    def test_latest_skips_corrupted(self, tmp_path):
        tree = {"a": jnp.arange(3)}
        ckpt.save(str(tmp_path), 1, tree)
        ckpt.save(str(tmp_path), 2, tree)
        # corrupt step 2: delete a leaf file
        for f in os.listdir(tmp_path / "ckpt_2"):
            if f.endswith(".npy"):
                os.remove(tmp_path / "ckpt_2" / f)
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_atomic_commit_no_partial(self, tmp_path):
        """A .tmp dir (simulating a crash mid-write) is never resumed from."""
        tree = {"a": jnp.arange(3)}
        ckpt.save(str(tmp_path), 1, tree)
        os.makedirs(tmp_path / "ckpt_9.tmp")
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_gc_keeps_newest(self, tmp_path):
        tree = {"a": jnp.arange(3)}
        for s in (1, 2, 3, 4):
            ckpt.save(str(tmp_path), s, tree)
        ckpt.gc(str(tmp_path), keep=2)
        assert ckpt.steps(str(tmp_path)) == [3, 4]


class TestFaultTolerance:
    def test_restart_resumes_exactly(self, tmp_path):
        """Training with an injected failure at step 7 must finish all steps
        and reproduce the no-failure loss trajectory after the restart."""
        tc = TrainConfig(batch=4, seq=32, ckpt_dir=str(tmp_path),
                         ckpt_every=5, total_steps=12,
                         optimizer=AdamWConfig(lr=1e-3))
        inj = FailureInjector({7})
        trainer = run_with_restarts(lambda: Trainer(CFG, tc,
                                                    failure_injector=inj),
                                    total_steps=12)
        assert trainer.step == 12
        # reference run without failure
        tc2 = TrainConfig(batch=4, seq=32, ckpt_dir=None, total_steps=12,
                          optimizer=AdamWConfig(lr=1e-3))
        ref = Trainer(CFG, tc2)
        ref.train(12)
        ref_losses = {m["step"]: m["loss"] for m in ref.metrics_log}
        for m in trainer.metrics_log:  # post-restart steps
            assert m["loss"] == pytest.approx(ref_losses[m["step"]],
                                              rel=1e-4), m["step"]

    def test_async_checkpoint_roundtrip(self, tmp_path):
        """async_ckpt overlaps I/O with training and produces checkpoints
        that resume identically to synchronous ones."""
        tc = TrainConfig(batch=4, seq=32, ckpt_dir=str(tmp_path),
                         ckpt_every=4, total_steps=8, async_ckpt=True,
                         optimizer=AdamWConfig(lr=1e-3))
        tr = Trainer(CFG, tc)
        tr.train(8)
        assert ckpt.latest_step(str(tmp_path)) == 8
        tree, manifest = ckpt.load(str(tmp_path), verify=True)
        np.testing.assert_array_equal(
            np.asarray(tree["params"]["embed"]["table"]),
            np.asarray(jax.device_get(tr.params["embed"]["table"])))

    def test_straggler_monitor(self):
        mon = StragglerMonitor(window=20, z_threshold=5.0, min_samples=5)
        for i in range(10):
            assert mon.record(i, 0.1 + 0.001 * (i % 3)) is None
        ev = mon.record(10, 2.0)  # 20× median
        assert ev is not None and ev.z > 5

    def test_injector_fires_once(self):
        inj = FailureInjector({3})
        with pytest.raises(RuntimeError):
            inj.maybe_fail(3)
        inj.maybe_fail(3)  # second call: already fired, no raise


class TestDataPipeline:
    def test_deterministic_per_step(self):
        p1 = TokenPipeline(CFG, 4, 32, PipelineConfig(seed=1))
        p2 = TokenPipeline(CFG, 4, 32, PipelineConfig(seed=1))
        b1, b2 = p1.get_batch(5), p2.get_batch(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = p1.get_batch(6)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_labels_shifted(self):
        p = TokenPipeline(CFG, 2, 16)
        b = p.get_batch(0)
        assert b["tokens"].shape == b["labels"].shape
        assert (b["tokens"] < CFG.vocab).all()

    def test_vlm_prefix(self):
        cfg = reduced(get_config("paligemma-3b"))
        p = TokenPipeline(cfg, 2, 16)
        b = p.get_batch(0)
        assert "prefix_embeds" in b
        assert b["prefix_embeds"].shape == (2, cfg.n_prefix_tokens,
                                            cfg.d_model)


class TestServe:
    def test_quantized_generation_close_to_fp(self):
        from repro.serve.engine import (ServeEngine, quantization_error,
                                        quantize_params_int8)
        eng = ServeEngine(CFG, max_len=48)
        qtree, dequant = quantize_params_int8(eng.params)
        assert quantization_error(eng.params, qtree, dequant) < 0.02
        batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
        toks, stats = eng.generate(batch, 6)
        assert toks.shape == (2, 6)
        assert stats.ttft_s > 0 and stats.itl_s > 0
        engq = ServeEngine(CFG, params=eng.params, max_len=48, quantize=True)
        toksq, _ = engq.generate(batch, 6)
        assert toksq.shape == (2, 6)
