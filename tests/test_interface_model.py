"""Paper §4.1/§4.3 fidelity: interface model, canonicalization, synthesis."""

import itertools
import os

import pytest

if os.environ.get("CI", "").lower() not in ("", "0", "false"):
    # CI must run the interface-model properties, never skip them (the
    # workflow installs the dev extra; see tests/test_egraph.py).
    import hypothesis  # noqa: F401
else:
    pytest.importorskip(
        "hypothesis", reason="install the dev extra: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

from repro.core import aquas_ir as ir
from repro.core.interface_model import (
    MemInterface,
    approx_latency,
    paper_example_interfaces,
    sequence_latency,
    tpu_interfaces,
)
from repro.core.synthesis import (
    elide_scratchpads,
    schedule_transactions,
    select_interfaces,
    synthesize,
)


class TestModel:
    def test_legal_transactions(self):
        bus = paper_example_interfaces()["busitfc"]
        assert bus.is_legal_transaction(64)
        assert bus.is_legal_transaction(4)
        assert not bus.is_legal_transaction(12)   # not a power-of-two beats
        assert not bus.is_legal_transaction(128)  # exceeds M·W
        assert not bus.is_legal_transaction(8, addr=4)  # misaligned

    def test_figure4b_canonicalization(self):
        """Paper Fig. 4(b): a 108-byte request on the system bus decomposes
        into 64-, 32-, 8-, and 4-byte legal transfers."""
        bus = paper_example_interfaces()["busitfc"]
        assert bus.decompose(108) == [64, 32, 8, 4]

    def test_recurrence_single_transaction(self):
        itf = MemInterface("t", W=4, M=1, I=1, L=2, E=1, C=64)
        # a_1 = 1 + max(-1, -1) = 0;  b_1 = 1 + max(-1, 0+2-1) = 2
        assert sequence_latency(itf, [4], "load") == 2
        # store: b_1 = 1 + 1 + max(-1, -1) = 1
        assert sequence_latency(itf, [4], "store") == 1

    def test_recurrence_inflight_pipelining(self):
        """I=2 overlaps two loads; I=1 serializes them."""
        i1 = MemInterface("a", W=4, M=1, I=1, L=6, E=1, C=64)
        i2 = MemInterface("b", W=4, M=1, I=2, L=6, E=1, C=64)
        sizes = [4] * 8
        assert sequence_latency(i2, sizes, "load") < \
            sequence_latency(i1, sizes, "load")

    def test_figure2_suboptimal_gap(self):
        """Paper Fig. 2: improper interface selection costs extra cycles —
        the narrow low-latency port loses to the burst bus on a bulk load."""
        itfcs = paper_example_interfaces()
        cpu, bus = itfcs["cpuitfc"], itfcs["busitfc"]
        m = 108
        lat_cpu = sequence_latency(cpu, cpu.decompose(m), "load")
        lat_bus = sequence_latency(bus, bus.decompose(m), "load")
        assert lat_bus < lat_cpu
        assert lat_cpu - lat_bus >= 7  # paper: "7- to 9-cycle penalty" scale

    @given(st.lists(st.sampled_from([4, 8, 16, 32, 64]), min_size=1,
                    max_size=12),
           st.sampled_from(["load", "store"]))
    @settings(max_examples=50, deadline=None)
    def test_latency_monotone_in_sequence(self, sizes, direction):
        """Adding a transaction never reduces completion time; latency is
        positive; approximation model stays within 3x of the recurrence."""
        bus = paper_example_interfaces()["busitfc"]
        full = sequence_latency(bus, sizes, direction)
        prefix = sequence_latency(bus, sizes[:-1], direction)
        assert full >= prefix
        assert full > 0
        approx = approx_latency(bus, [[s] for s in sizes], direction)
        assert approx <= 3 * full + 10
        assert full <= 3 * approx + 10

    def test_tpu_interfaces_sane(self):
        t = tpu_interfaces()
        assert t["hbm_vmem"].W * t["hbm_vmem"].M >= 512 * 1024  # big bursts
        assert t["vmem_vreg"].L < t["hbm_vmem"].L < t["ici_link"].L


def _fir7_program():
    """The paper's fir7 kernel: src (108B), coef (28B, warm), bias (28B,
    elidable — per-element loads hide behind the MAC chain)."""
    sp = {
        "bias": ir.ScratchpadDecl("bias", 28, ir.CacheHint.WARM,
                                  compute_cycles_per_elem=8.0, elem_bytes=4),
        "coef": ir.ScratchpadDecl("coef", 28, ir.CacheHint.WARM,
                                  reuse_factor=7, elem_bytes=4),
    }
    ops = [
        ir.FuncOp("transfer", "src", 108, ir.Space.GLOBAL,
                  ir.Space.SCRATCHPAD, "load", ir.CacheHint.COLD),
        ir.FuncOp("transfer", "coef", 28, ir.Space.GLOBAL,
                  ir.Space.SCRATCHPAD, "load", ir.CacheHint.WARM,
                  scratchpad="coef"),
        ir.FuncOp("transfer", "bias", 28, ir.Space.GLOBAL,
                  ir.Space.SCRATCHPAD, "load", ir.CacheHint.WARM,
                  scratchpad="bias"),
        ir.FuncOp("read_smem", "bias_rd", 28, ir.Space.SCRATCHPAD,
                  ir.Space.REG, "load", scratchpad="bias"),
        ir.FuncOp("transfer", "dst", 80, ir.Space.REG, ir.Space.GLOBAL,
                  "store", ir.CacheHint.COLD),
    ]
    return ir.FunctionalProgram("fir7", ops, sp)


class TestSynthesis:
    def test_elision_decisions(self):
        """bias elides (latency hidden); coef kept (reuse would thrash)."""
        prog = _fir7_program()
        out, decisions = elide_scratchpads(prog, paper_example_interfaces())
        assert decisions["scratchpad:bias"] == "elided"
        assert decisions["scratchpad:coef"] == "kept"
        assert "bias" not in out.scratchpads
        assert "coef" in out.scratchpads
        kinds = [(o.kind, o.name) for o in out.ops]
        assert ("fetch", "bias_rd") in kinds          # read_smem → fetch
        assert ("transfer", "bias") not in kinds      # staging removed

    def test_elision_respects_legality_guards(self):
        itfcs = paper_example_interfaces()
        sp = ir.ScratchpadDecl("t", 28, accessed_in_unrolled_region=True,
                               compute_cycles_per_elem=100.0)
        prog = ir.FunctionalProgram("p", [
            ir.FuncOp("transfer", "t", 28, ir.Space.GLOBAL,
                      ir.Space.SCRATCHPAD, "load", scratchpad="t")],
            {"t": sp})
        _, decisions = elide_scratchpads(prog, itfcs)
        assert decisions["scratchpad:t"] == "kept"

    def test_interface_selection_routes_bulk_to_bus(self):
        """Paper §4.3: the 108-byte src goes over the high-bandwidth bus."""
        prog, _ = elide_scratchpads(_fir7_program(),
                                    paper_example_interfaces())
        arch = select_interfaces(prog, paper_example_interfaces())
        assert arch.decisions["itfc:src"] == "busitfc"
        src_ops = [o for o in arch.ops if o.name == "src"]
        assert [o.size_bytes for o in src_ops] == [64, 32, 8, 4]

    def test_selection_is_optimal_vs_bruteforce(self):
        """The chosen assignment achieves the brute-force-minimal objective."""
        from repro.core.synthesis import _assign_exact, _objective
        itfcs = list(paper_example_interfaces().values())
        ops = [ir.FuncOp("fetch", f"q{i}", sz, ir.Space.GLOBAL, ir.Space.REG,
                         "load")
               for i, sz in enumerate([4, 28, 64, 108])]
        assign, cost = _assign_exact(ops, itfcs, "load")
        for trial in itertools.product(range(len(itfcs)), repeat=len(ops)):
            assert cost <= _objective(trial, ops, itfcs, "load") + 1e-9

    def test_schedule_beats_naive_order(self):
        """Memoized transaction ordering ≤ any fixed order (paper Fig. 3)."""
        itfcs = paper_example_interfaces()
        prog, _ = elide_scratchpads(_fir7_program(), itfcs)
        arch = select_interfaces(prog, itfcs)
        temporal = schedule_transactions(arch)
        assert temporal.total_cycles > 0
        issues = [o for o in temporal.ops if o.kind == "copy_issue"]
        waits = [o for o in temporal.ops if o.kind == "copy_wait"]
        assert issues and waits
        # after-chains are well-formed: each issue after its predecessor
        ids = {o.op_id for o in temporal.ops}
        for o in temporal.ops:
            assert o.after is None or o.after in ids

    def test_full_pipeline_decisions_logged(self):
        t = synthesize(_fir7_program(), paper_example_interfaces())
        assert "scratchpad:bias" in t.decisions
        assert any(k.startswith("itfc:") for k in t.decisions)
        assert any(k.startswith("order:") for k in t.decisions)

    @given(st.integers(1, 512))
    @settings(max_examples=40, deadline=None)
    def test_decompose_covers_request(self, m):
        """Decomposition covers ≥ m bytes with only legal sizes (property)."""
        for itf in paper_example_interfaces().values():
            chunks = itf.decompose(m)
            assert sum(chunks) >= m
            assert sum(chunks) < m + itf.W
            for c in chunks:
                assert itf.is_legal_transaction(c)


class TestKernelSynth:
    def test_matmul_blocks_fit_and_align(self):
        from repro.core.interface_model import MXU_DIM, TPU_VMEM_BUDGET
        from repro.core.kernel_synth import choose_matmul_blocks
        s = choose_matmul_blocks(4096, 4096, 4096)
        assert s.vmem_bytes <= TPU_VMEM_BUDGET
        assert s.block("b")[1] % MXU_DIM == 0
        # compute-bound GEMM: BlockSpec's implicit double buffering already
        # hides the DMA, so the explicit burst pipeline must not be selected
        assert not s.pipelined
        # memory-bound skinny GEMM: deep burst staging predicted to win
        skinny = choose_matmul_blocks(8, 4096, 8192, dtype_bytes=1)
        assert skinny.pipelined and skinny.buffering > 2

    def test_flash_blocks_prefer_streaming_kv(self):
        from repro.core.kernel_synth import choose_flash_blocks
        s = choose_flash_blocks(4096, 4096, 128)
        assert s.decisions["kv_hint"] == "cold"
        assert s.decisions["q_hint"] == "warm"
        assert s.vmem_bytes <= 64 * 1024 * 1024

    def test_ssd_blocks(self):
        from repro.core.kernel_synth import choose_ssd_blocks
        s = choose_ssd_blocks(4096, 80, 64, 128)
        assert s.block("chunk")[0] in (128, 256, 512)
