"""Point-cloud vertical: e-graph matching of the fps/ball_query/group_agg
ISAXes from divergent software spellings, interpret-mode kernel parity
(fp32/bf16, baseline + burst-pipelined), dispatch cache behavior, and the
burst-pipeline loss veto on compute-bound grouping shapes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.compile import Dispatcher, LoweringConfig, OpKey
from repro.compile.trace import trace_term
from repro.core.kernel_synth import (
    PIPELINE_GAIN_MIN,
    choose_ball_blocks,
    choose_fps_blocks,
    choose_group_blocks,
)
from repro.core.offload import compile_program, evaluate
from repro.targets import isax_library
from repro.pointcloud import kernels as pck
from repro.pointcloud import ops as pcops
from repro.pointcloud import ref as pcref

RNG = np.random.default_rng(0)
B, N, M, K, C = 2, 256, 64, 8, 32
RADIUS = 0.9


def _cloud(dtype=jnp.float32):
    xyz = jnp.asarray(RNG.normal(size=(B, N, 3)), dtype)
    feats = jnp.asarray(RNG.normal(size=(B, N, C)), dtype)
    return xyz, feats


# ---------------------------------------------------------------------------
# (a) e-graph compilation: divergent spellings land on the ISAXes, and the
#     offloaded programs evaluate identically to the originals
# ---------------------------------------------------------------------------

class TestEGraphMatching:
    @pytest.mark.parametrize("kind,want", [
        ("fps", "fps"),
        ("ball_query", "ball_query"),
        ("group_aggregate", "group_agg"),
    ])
    def test_divergent_spelling_matches(self, kind, want):
        res = compile_program(trace_term(kind), isax_library(), case=kind)
        assert want in res.stats.matched_isaxes
        # fps/ball_query require the sqdist bridge, group_agg the
        # neg∘min∘neg bridge — matching must be a saturation theorem,
        # not string equality
        assert res.stats.internal_rewrites > 0

    def test_matmul_negative_control_still_clean(self):
        res = compile_program(trace_term("matmul"), isax_library(),
                              case="matmul")
        assert res.stats.matched_isaxes == []

    def test_offloaded_fps_evaluates_identically(self):
        n, n_s = 48, 6
        X = RNG.normal(size=(n, 3))
        env = dict(Xp=X, n_s=n_s, Dp=np.full((1, n), 1e30),
                   Sp=np.zeros(n_s, np.int64))
        env2 = {k: (v.copy() if isinstance(v, np.ndarray) else v)
                for k, v in env.items()}
        res = compile_program(trace_term("fps"), isax_library(), case="fps")
        evaluate(trace_term("fps"), env)
        evaluate(res.program, env2)
        np.testing.assert_array_equal(env["Sp"], env2["Sp"])
        np.testing.assert_allclose(env["Dp"], env2["Dp"], atol=1e-9)

    def test_offloaded_ball_and_group_evaluate_identically(self):
        n, m, k, c = 64, 8, 4, 6
        X = RNG.normal(size=(n, 3))
        Cn = X[:m]
        F = RNG.normal(size=(n, c))
        env = dict(Xp=X, Cn=Cn, r2=1.0, kk=k, n_c=m,
                   Gq=np.zeros((m, k), np.int64))
        env2 = {key: (v.copy() if isinstance(v, np.ndarray) else v)
                for key, v in env.items()}
        res = compile_program(trace_term("ball_query"), isax_library(),
                              case="ballq")
        evaluate(trace_term("ball_query"), env)
        evaluate(res.program, env2)
        np.testing.assert_array_equal(env["Gq"], env2["Gq"])

        genv = dict(Fg=F, Gq=env["Gq"], n_c=m, Ag=np.zeros((m, c)))
        genv2 = {key: (v.copy() if isinstance(v, np.ndarray) else v)
                 for key, v in genv.items()}
        res = compile_program(trace_term("group_aggregate"), isax_library(),
                              case="groupagg")
        evaluate(trace_term("group_aggregate"), genv)
        evaluate(res.program, genv2)
        np.testing.assert_allclose(genv["Ag"], genv2["Ag"], atol=1e-12)


# ---------------------------------------------------------------------------
# (b) interpret-mode kernel parity vs the jnp references
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
class TestKernelParity:
    def test_fps_exact(self, dtype):
        xyz, _ = _cloud(dtype)
        got = pck.fps(xyz, M, interpret=True)
        want = pcref.fps_ref(xyz, M)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_ball_query_exact(self, dtype):
        xyz, _ = _cloud(dtype)
        centers = xyz[:, :M]
        want = pcref.ball_query_ref(xyz, centers, RADIUS, K)
        got = pck.ball_query(xyz, centers, RADIUS, K, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        gotp = pck.ball_query_pipelined(xyz, centers, RADIUS, K, depth=3,
                                        interpret=True)
        np.testing.assert_array_equal(np.asarray(gotp), np.asarray(want))

    def test_group_aggregate_exact(self, dtype):
        xyz, feats = _cloud(dtype)
        idx = pcref.ball_query_ref(xyz, xyz[:, :M], RADIUS, K)
        want = np.asarray(pcref.group_aggregate_ref(feats, idx), np.float32)
        got = pck.group_aggregate(feats, idx, interpret=True)
        np.testing.assert_array_equal(np.asarray(got, np.float32), want)
        gotp = pck.group_aggregate_pipelined(feats, idx, depth=3,
                                             interpret=True)
        np.testing.assert_array_equal(np.asarray(gotp, np.float32), want)


def test_wrapper_ref_fallback_on_untileable_shapes():
    # 65 centers / 200 points: the largest power-of-two divisors (1 and 8)
    # degrade below the meaningful tile minimum, so pc_tiles reports the
    # shape untileable and the wrappers take the reference path
    xyz = jnp.asarray(RNG.normal(size=(1, 200, 3)), jnp.float32)
    centers = xyz[:, :65]
    assert pcops.pc_tiles(65, 200, pcops._ball_schedule(65, 200, K, 4),
                          "x") is None
    got = pcops.ball_query(xyz, centers, RADIUS, K, interpret=True)
    want = pcref.ball_query_ref(xyz, centers, RADIUS, K)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    feats = jnp.asarray(RNG.normal(size=(1, 200, C)), jnp.float32)
    gota = pcops.group_aggregate(feats, got, interpret=True)
    np.testing.assert_allclose(
        np.asarray(gota),
        np.asarray(pcref.group_aggregate_ref(feats, got)), atol=1e-6)
    assert np.asarray(pcops.farthest_point_sample(
        xyz, 300, interpret=True)).shape == (1, 300)  # S > N → ref


def test_dispatch_falls_back_on_untileable_and_oversized_shapes():
    lw = LoweringConfig("pallas_interpret", Dispatcher())
    rec = lw.lower("ball_query", (1, 200, 65, K), "float32")
    assert rec.impl == "reference" and "untileable" in rec.note
    assert rec.target_matched  # matched, not extracted
    rec = lw.lower("group_aggregate", (1, 200, 65, K, C), "float32")
    assert rec.impl == "reference" and "untileable" in rec.note
    # FPS has no tiling: a cloud too large for VMEM lowers to the reference
    rec = lw.lower("fps", (1, 8_000_000, 64), "float32")
    assert rec.impl == "reference" and "VMEM" in rec.note


# ---------------------------------------------------------------------------
# (c) dispatch: ISAX extraction, cache-key round trip
# ---------------------------------------------------------------------------

class TestDispatch:
    def test_all_three_ops_extract_isax(self):
        disp = Dispatcher()
        lw = LoweringConfig("pallas_interpret", disp)
        for op, shape in (("fps", (B, N, M)),
                          ("ball_query", (B, N, M, K)),
                          ("group_aggregate", (B, N, M, K, C))):
            rec = lw.lower(op, shape, "float32")
            assert rec.impl == "isax", f"{op}: {rec.note}"
            assert rec.target_matched
            assert rec.kernel_fn is not None
            assert "pipelined" in rec.schedule

    def test_cache_key_round_trip(self):
        disp = Dispatcher()
        lw = LoweringConfig("pallas_interpret", disp)
        key = ("ball_query", (B, N, M, K), "float32")
        r1 = lw.lower(*key)
        assert disp.misses == 1 and disp.hits == 0
        r2 = lw.lower(*key)
        assert r2 is r1 and disp.hits == 1
        # dtype and backend are part of the key
        r3 = lw.lower("ball_query", (B, N, M, K), "bfloat16")
        assert r3 is not r1
        r4 = LoweringConfig("xla", disp).lower(*key)
        assert r4 is not r1 and r4.impl == "reference"
        assert disp.records[OpKey("ball_query", (B, N, M, K), "float32",
                                  "pallas_interpret")] is r1

    def test_lowering_config_set_abstraction_parity(self):
        xyz, feats = _cloud()
        lw = LoweringConfig("pallas_interpret", Dispatcher())
        sel = lw.fps(xyz, M)
        centers = jnp.take_along_axis(xyz, sel[..., None], axis=1)
        idx = lw.ball_query(xyz, centers, RADIUS, K)
        agg = lw.group_aggregate(feats, idx)
        np.testing.assert_array_equal(np.asarray(sel),
                                      np.asarray(pcref.fps_ref(xyz, M)))
        np.testing.assert_array_equal(
            np.asarray(idx),
            np.asarray(pcref.ball_query_ref(xyz, centers, RADIUS, K)))
        np.testing.assert_allclose(
            np.asarray(agg),
            np.asarray(pcref.group_aggregate_ref(feats, idx)), atol=1e-6)


# ---------------------------------------------------------------------------
# (d) synthesis: burst-pipeline decisions, loss veto
# ---------------------------------------------------------------------------

class TestPipelineDecisions:
    def test_fps_never_pipelined(self):
        sched = choose_fps_blocks(2048, 128)
        assert sched.buffering == 1 and not sched.pipelined

    def test_memory_bound_grouping_selects_pipeline(self):
        sched = choose_group_blocks(64, 4096, 8, 64)
        assert sched.pipelined and sched.buffering > 1
        assert sched.pipeline_gain >= PIPELINE_GAIN_MIN

    def test_compute_bound_grouping_vetoes_pipeline(self):
        # bm·k·2/dtype_bytes ≫ MXU-to-HBM flops/byte ridge: the one-hot
        # gather matmul dominates and deeper staging cannot pay off
        sched = choose_group_blocks(512, 512, 64, 256)
        assert not sched.pipelined and sched.buffering == 1
        assert sched.est_total_cycles <= sched.est_serial_cycles * (1 + 1e-9)

    @pytest.mark.parametrize("sched_fn", [
        lambda: choose_ball_blocks(256, 4096, 16),
        lambda: choose_group_blocks(64, 4096, 8, 64),
        lambda: choose_group_blocks(512, 512, 64, 256),
        lambda: choose_fps_blocks(1024, 64),
    ])
    def test_never_selected_on_predicted_loss(self, sched_fn):
        sched = sched_fn()
        assert sched.pipelined == (sched.pipeline_gain >= PIPELINE_GAIN_MIN
                                   and sched.buffering > 1)
