"""Elastic re-scaling: a checkpoint written under one mesh/sharding restores
onto a *different* mesh (the 1000-node story: train on N pods, resume on M).
Runs in a subprocess with 8 forced host devices (same pattern as
test_pipeline.py)."""

import os
import subprocess
import sys

SCRIPT = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt

mesh_a = jax.make_mesh((2, 4), ("data", "model"))
mesh_b = jax.make_mesh((4, 2), ("data", "model"))

rng = np.random.default_rng(0)
w = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
tree = {
    "w": jax.device_put(w, NamedSharding(mesh_a, P("data", "model"))),
    "b": jax.device_put(jnp.arange(32, dtype=jnp.float32),
                        NamedSharding(mesh_a, P("model"))),
}
d = tempfile.mkdtemp()
ckpt.save(d, 5, tree)

# restore under mesh B with different shardings
shardings = {
    "w": NamedSharding(mesh_b, P("model", "data")),
    "b": NamedSharding(mesh_b, P(None)),
}
loaded, manifest = ckpt.load(d, shardings=shardings, verify=True)
assert manifest["step"] == 5
np.testing.assert_array_equal(np.asarray(loaded["w"]), np.asarray(w))
got_spec = loaded["w"].sharding.spec
assert got_spec == P("model", "data"), got_spec
assert loaded["w"].sharding.mesh.devices.shape == (4, 2)
print("ELASTIC_OK")
"""


def test_checkpoint_restores_across_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "ELASTIC_OK" in out.stdout, (out.stdout[-2000:],
                                        out.stderr[-2000:])
