"""E-graph engine invariants (paper §2.3/§5.2) — unit + hypothesis property."""

import os

import numpy as np
import pytest

if os.environ.get("CI", "").lower() not in ("", "0", "false"):
    # In CI the property suites must gate merges: the workflow installs the
    # dev extra, so a missing hypothesis is an environment bug — fail loud
    # instead of silently skipping the semantic-preservation properties.
    # (CI=0/false is the conventional local opt-out, hence the truthiness.)
    import hypothesis  # noqa: F401
else:
    pytest.importorskip(
        "hypothesis", reason="install the dev extra: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

from repro.core import expr
from repro.core.egraph import EGraph, Rewrite, run_rewrites
from repro.core.expr import const, var
from repro.core.offload import evaluate
from repro.core.rewrites import internal_rules, saturate_internal


class TestEGraphBasics:
    def test_hashcons_dedup(self):
        eg = EGraph()
        a = eg.add_term(("+", var("x"), const(1)))
        b = eg.add_term(("+", var("x"), const(1)))
        assert eg.find(a) == eg.find(b)

    def test_union_find(self):
        eg = EGraph()
        a = eg.add_term(var("a"))
        b = eg.add_term(var("b"))
        c = eg.add_term(var("c"))
        eg.union(a, b)
        eg.union(b, c)
        assert eg.find(a) == eg.find(c)

    def test_congruence_closure(self):
        eg = EGraph()
        fa = eg.add_term(("exp", var("a")))
        fb = eg.add_term(("exp", var("b")))
        assert eg.find(fa) != eg.find(fb)
        eg.union(eg.add_term(var("a")), eg.add_term(var("b")))
        eg.rebuild()
        assert eg.find(fa) == eg.find(fb)

    def test_congruence_two_levels(self):
        eg = EGraph()
        ffa = eg.add_term(("exp", ("neg", var("a"))))
        ffb = eg.add_term(("exp", ("neg", var("b"))))
        eg.union(eg.add_term(var("a")), eg.add_term(var("b")))
        eg.rebuild()
        assert eg.find(ffa) == eg.find(ffb)

    def test_ematch_binds_consistently(self):
        eg = EGraph()
        eg.add_term(("+", var("x"), var("x")))
        eg.add_term(("+", var("x"), var("y")))
        same = eg.ematch(("+", ("?a",), ("?a",)))
        assert len(same) == 1

    def test_extraction_minimizes(self):
        eg = EGraph()
        expensive = eg.add_term(("<<", var("i"), const(2)))
        cheap = eg.add_term(("*", var("i"), const(4)))
        eg.union(expensive, cheap)
        eg.rebuild()
        cost = lambda op, cc: (50.0 if op == "<<" else 1.0) + sum(cc)
        out = eg.extract(eg.find(expensive), cost)
        assert expr.op(out) == "*"

    def test_rewrite_nondestructive(self):
        """Union keeps both variants available (the e-graph accumulates)."""
        eg = EGraph()
        root = eg.add_term(("<<", var("i"), const(2)))
        run_rewrites(eg, internal_rules(), max_iters=3)
        nodes = {n[0] for n in eg.nodes_of(root)}
        assert "<<" in nodes and "*" in nodes


# --- hypothesis: semantic preservation under saturation ---------------------

_leaf = st.sampled_from([var("x"), var("y"), const(2), const(3), const(5)])


def _terms(depth):
    if depth == 0:
        return _leaf
    sub = _terms(depth - 1)
    return st.one_of(
        _leaf,
        st.tuples(st.sampled_from(["+", "*", "-"]), sub, sub).map(tuple),
        st.tuples(st.just("<<"), sub, st.sampled_from([const(1), const(2)])
                  ).map(tuple),
    )


@given(_terms(3), st.integers(-3, 3), st.integers(-3, 3))
@settings(max_examples=60, deadline=None)
def test_saturation_preserves_semantics(term, xv, yv):
    """Any extraction from the saturated e-graph evaluates identically."""
    env = {"x": np.int64(xv), "y": np.int64(yv)}
    try:
        want = evaluate(term, dict(env))
    except Exception:
        return  # skip invalid shifts etc.
    eg = EGraph(node_limit=20_000)
    root = eg.add_term(term)
    saturate_internal(eg, max_iters=3)
    cost = lambda op, cc: 1.0 + sum(cc)
    got_term = eg.extract(eg.find(root), cost)
    got = evaluate(got_term, dict(env))
    assert np.allclose(np.float64(want), np.float64(got)), (term, got_term)


@given(_terms(2))
@settings(max_examples=40, deadline=None)
def test_add_term_idempotent(term):
    eg = EGraph()
    a = eg.add_term(term)
    b = eg.add_term(term)
    assert eg.find(a) == eg.find(b)
    n = eg.n_nodes()
    eg.add_term(term)
    assert eg.n_nodes() == n


@given(_terms(2), _terms(2))
@settings(max_examples=30, deadline=None)
def test_union_symmetric_idempotent(t1, t2):
    eg1 = EGraph()
    a1, b1 = eg1.add_term(t1), eg1.add_term(t2)
    eg1.union(a1, b1)
    eg1.rebuild()
    eg2 = EGraph()
    a2, b2 = eg2.add_term(t1), eg2.add_term(t2)
    eg2.union(b2, a2)
    eg2.union(a2, b2)
    eg2.rebuild()
    assert (eg1.find(a1) == eg1.find(b1)) == (eg2.find(a2) == eg2.find(b2))
    assert eg1.n_classes() == eg2.n_classes()


def test_normalize_indices_idempotent_and_alpha():
    t = expr.for_("k", const(0), const(8), const(1),
                  ("store", ("arr:A",), var("k"),
                   ("+", var("k"), var("free"))))
    n1 = expr.normalize_indices(t)
    n2 = expr.normalize_indices(n1)
    assert n1 == n2
    assert expr.op(n1) == "for:i0"
    # free vars survive; bound var renamed
    leaves = {expr.op(u) for u in expr.walk(n1) if expr.is_leaf(u)}
    assert "var:free" in leaves and "var:i0" in leaves and "var:k" not in leaves


def test_loop_structure_summary():
    t = expr.for_("i", const(0), const(8), const(2),
                  expr.for_("j", const(0), const(4), const(1),
                            ("store", ("arr:A",), var("j"), var("j"))))
    s = expr.loop_structure(t)
    assert s == (4, 2, ((4, 1, ()),))
