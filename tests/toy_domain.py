"""A toy third application domain in ONE file — the acceptance proof of the
registry redesign.

This module is everything a new domain needs: a divergent software trace
program, an ISAX skeleton/component definition, numpy evaluator semantics,
a scheduler, and a kernel entry point, bundled into a ``DomainPackage``.
The test suite registers it with **one line** into a fresh registry and the
unchanged generic dispatch engine matches, schedules, caches, and
dispatches it — no edit to ``compile/dispatch.py``, ``core/offload.py``,
or any other engine module.

The op is a scaled row accumulate ("axpy rows"): O[i] = a·X[i] + Y[i].
The software spelling commutes both operands (Y first, scale on the right)
so matching requires the ``add-comm``/``mul-comm`` internal rewrites —
a real (if small) saturation theorem, not string equality.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.expr import arr, const, for_, var
from repro.core.matching import ISAX
from repro.core.tiling import down_pow2
from repro.targets.registry import DomainPackage, IsaxSpec


def _axpy_program():
    """Software spelling: O[i] = Y[i] + (X[i] * a) — commuted twice."""
    i = var("i")
    return for_("i", const(0), var("n"), const(1),
                ("store", arr("Oy"), i,
                 ("+", ("load", arr("Y"), i),
                  ("*", ("load", arr("X"), i), var("a")))))


def isax_axpy() -> ISAX:
    """ISAX spelling: O[i] = a * X[i] + Y[i]."""
    i = var("i")
    term = for_("i", const(0), var("n"), const(1),
                ("store", arr("Oy"), i,
                 ("+", ("*", var("a"), ("load", arr("X"), i)),
                  ("load", arr("Y"), i))))
    return ISAX(
        name="axpy",
        params=("X", "Y", "a", "n", "Oy"),
        term=term,
        kernel="axpy",
        outputs=("Oy",),
    )


def _np_axpy(X, Y, a, n, Oy):
    Oy[:] = a * X + Y


def _axpy_schedule(key):
    rows, d = key.shape
    return {"block_rows": down_pow2(rows, 128)}, "ok"


def axpy_kernel(x, y, a, *, interpret: bool = False):
    """The "hardware" entry point (jnp stands in for a Pallas kernel: the
    dispatch contract only requires a bound callable)."""
    return a * jnp.asarray(x) + jnp.asarray(y)


def axpy_ref(x, y, a):
    """Reference oracle for parity checks."""
    return np.asarray(a) * np.asarray(x) + np.asarray(y)


DOMAIN = DomainPackage(
    name="toy",
    description="Single-file third domain proving registry retargetability.",
    specs=(
        IsaxSpec(
            name="axpy",
            isax=isax_axpy,
            evaluator=_np_axpy,
            trace_kind="axpy",
            trace_program=_axpy_program,
            ops=("axpy",),
            rewrites=("add-comm", "mul-comm"),
            scheduler=_axpy_schedule,
            kernel=axpy_kernel,
            description="Scaled row accumulate O[i] = a·X[i] + Y[i].",
        ),
    ),
)
