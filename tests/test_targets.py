"""Registry invariants for the declarative ISAX/domain lowering API.

Covers: registration invariants (duplicate names/ops rejected, every
dispatchable spec resolvable end to end), the golden-file compile-record
parity against the pre-refactor engine (the redesign moved wiring, not
decisions), trace-memo keying by spec identity (two domains can never
alias a trace kind), the single-file toy third domain dispatched by the
unchanged generic engine, and the deprecation shims for the old entry
points."""

import dataclasses
import json
import pathlib

import numpy as np
import pytest

from repro.compile import Dispatcher, LoweringConfig, OpKey
from repro.core.offload import evaluate
from repro.core.rewrites import internal_rules
from repro.targets import default_registry, isax_library
from repro.targets.registry import DomainPackage, IsaxSpec, TargetRegistry
from repro.targets import llm as llm_domain
from repro.targets import pointcloud as pc_domain

import toy_domain

GOLDEN = pathlib.Path(__file__).parent / "golden" / "dispatch_records.json"


# ---------------------------------------------------------------------------
# (a) registration invariants
# ---------------------------------------------------------------------------

class TestRegistration:
    def test_builtin_domains_loaded_in_order(self):
        reg = default_registry()
        assert list(reg.domains()) == ["llm", "pointcloud"]
        assert [i.name for i in reg.isaxes()] == [
            "flash_attention", "int8_matvec", "ssd_step", "rmsnorm",
            "swiglu", "fps", "ball_query", "group_agg"]
        assert reg.ops()[:3] == ["attention", "attention_decode",
                                 "attention_paged"]

    def test_duplicate_domain_rejected(self):
        reg = TargetRegistry()
        reg.register(llm_domain.DOMAIN)
        with pytest.raises(ValueError, match="already registered"):
            reg.register(llm_domain.DOMAIN)

    def test_duplicate_spec_name_rejected(self):
        reg = TargetRegistry()
        reg.register(llm_domain.DOMAIN)
        clash = DomainPackage("other", (dataclasses.replace(
            llm_domain.DOMAIN.specs[0], domain=None),))
        with pytest.raises(ValueError, match="duplicate ISAX spec name"):
            reg.register(clash)
        # the failed registration must not have leaked partial state
        assert "other" not in reg.domains()

    def test_duplicate_op_rejected(self):
        reg = TargetRegistry()
        reg.register(llm_domain.DOMAIN)
        spec = dataclasses.replace(toy_domain.DOMAIN.specs[0],
                                   ops=("attention",), domain=None)
        with pytest.raises(ValueError, match="duplicate dispatch op"):
            reg.register(DomainPackage("other", (spec,)))

    def test_incomplete_spec_rejected(self):
        broken = dataclasses.replace(toy_domain.DOMAIN.specs[0],
                                     kernel=None, domain=None)
        with pytest.raises(ValueError, match="kernel entry point"):
            TargetRegistry().register(DomainPackage("b", (broken,)))
        unnamed = dataclasses.replace(toy_domain.DOMAIN.specs[0],
                                      name="", domain=None)
        with pytest.raises(ValueError, match="non-empty name"):
            TargetRegistry().register(DomainPackage("b", (unnamed,)))

    def test_every_dispatchable_spec_resolves(self):
        """Every registered IsaxSpec with dispatch ops has a resolvable
        kernel entry point, scheduler, trace program, and — when matchable —
        evaluator semantics and a self-consistent ISAX definition."""
        reg = default_registry()
        for spec in reg.specs():
            spec.validate()
            if not spec.ops:
                continue
            assert callable(spec.trace_program)
            assert spec.trace_program() is not None
            if spec.isax is None:
                continue  # negative control: reference-only by design
            assert callable(spec.scheduler)
            assert callable(spec.kernel)
            assert callable(spec.evaluator)
            assert spec.isax().name == spec.name

    def test_declared_rewrites_exist(self):
        """Every bridging rewrite an IsaxSpec declares resolves against
        core/rewrites' internal rule set (docs can't name ghosts)."""
        names = {r.name for r in internal_rules()}
        for spec in default_registry().specs():
            missing = set(spec.rewrites) - names
            assert not missing, f"{spec.name}: unknown rewrites {missing}"


# ---------------------------------------------------------------------------
# (b) golden-file parity: the redesign moved wiring, not decisions
# ---------------------------------------------------------------------------

def test_golden_compile_record_parity():
    """All 11 pre-refactor dispatch keys produce identical CompileRecords
    (impl, matched set, schedule, note, saturated e-node count) through the
    registry engine.

    The internal/external rewrite *counters* are excluded from the strict
    compare: they were already PYTHONHASHSEED-dependent in the pre-registry
    engine (rule-application order follows string-hash iteration, e.g. the
    attention trace logs 461 or 469 internal rewrites depending on seed),
    so the golden file only pins their sign.
    """
    golden = json.loads(GOLDEN.read_text())
    assert len(golden) == 11
    counters = ("internal_rewrites", "external_rewrites")
    disp = Dispatcher()
    for want in golden:
        rec = disp.lower(OpKey(want["op"], tuple(want["shape"]),
                               want["dtype"], want["backend"]))
        got = rec.row()
        got.pop("hits")
        for c in counters:
            assert (got.pop(c) > 0) == (want[c] > 0), f"{want['op']}: {c}"
        want = {k: v for k, v in want.items() if k not in counters}
        assert got == want, f"{want['op']}{tuple(want['shape'])} diverged"


def test_cache_key_roundtrip_unchanged():
    """OpKey equality/hashing is untouched: the same logical key lowers to
    the same record object (the compile-cache invariant)."""
    disp = Dispatcher()
    a = disp.lower(OpKey("fps", (1, 256, 64), "float32", "pallas_interpret"))
    b = disp.lower(OpKey("fps", (1, 256, 64), "float32", "pallas_interpret"))
    assert a is b and disp.hits == 1


# ---------------------------------------------------------------------------
# (c) trace-memo keying: spec identity, never a kind string
# ---------------------------------------------------------------------------

def test_trace_memo_keyed_by_spec_identity():
    """Two domains reusing the same trace-kind *string* get independent
    saturation runs (the old memo keyed on the string and would have
    aliased them)."""
    toy_a = dataclasses.replace(toy_domain.DOMAIN.specs[0], domain=None)
    # a second domain that deliberately reuses trace_kind="axpy" but traces
    # the *matmul* negative-control program under its own op name
    matmul_spec = default_registry().spec("matmul")
    other = IsaxSpec(
        name="not_axpy",
        trace_kind="axpy",
        trace_program=matmul_spec.trace_program,
        ops=("not_axpy",),
    )
    reg = TargetRegistry()
    reg.register(DomainPackage("toy", (toy_a,)))
    reg.register(DomainPackage("other", (other,)))
    disp = Dispatcher(registry=reg)
    rec_a = disp.lower(OpKey("axpy", (8, 8), "float32", "pallas_interpret"))
    rec_b = disp.lower(OpKey("not_axpy", (8, 8), "float32",
                             "pallas_interpret"))
    assert len(disp._outcomes) == 2  # one memo entry per spec identity
    assert "axpy" in rec_a.matched
    assert rec_b.matched == () and rec_b.impl == "reference"


# ---------------------------------------------------------------------------
# (d) the single-file toy third domain through the unchanged engine
# ---------------------------------------------------------------------------

class TestToyDomain:
    @pytest.fixture()
    def lowering(self):
        reg = TargetRegistry()
        reg.register(llm_domain.DOMAIN)
        reg.register(pc_domain.DOMAIN)
        reg.register(toy_domain.DOMAIN)  # the one registration line
        return LoweringConfig.from_registry("pallas_interpret", registry=reg)

    def test_matched_scheduled_cached_dispatched(self, lowering):
        rec = lowering.lower("axpy", (64, 16), "float32")
        assert rec.impl == "isax", rec.note
        assert "axpy" in rec.matched
        assert rec.schedule == {"block_rows": 64}
        assert rec.kernel_fn is toy_domain.axpy_kernel
        again = lowering.lower("axpy", (64, 16), "float32")
        assert again is rec  # cached
        assert lowering.dispatcher.hits == 1

    def test_kernel_parity_through_dispatch(self, lowering):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(64, 16)).astype(np.float32)
        y = rng.normal(size=(64, 16)).astype(np.float32)
        rec = lowering.lower("axpy", (64, 16), "float32")
        got = np.asarray(rec.kernel_fn(x, y, 0.5,
                                       interpret=lowering.interpret))
        np.testing.assert_allclose(got, toy_domain.axpy_ref(x, y, 0.5),
                                   rtol=1e-6)

    def test_evaluator_parity(self, lowering):
        """The offloaded program's isax:axpy intrinsic (spec evaluator)
        reproduces the software program's numerics."""
        rng = np.random.default_rng(1)
        n, d = 8, 4

        def env():
            return dict(X=rng.normal(size=(n, d)).copy(),
                        Y=rng.normal(size=(n, d)).copy(),
                        a=0.25, n=n, Oy=np.zeros((n, d)))

        from repro.core.offload import compile_program
        res = compile_program(toy_domain._axpy_program(),
                              lowering.registry.isaxes(), case="toy")
        assert "axpy" in res.stats.matched_isaxes
        e_sw, e_hw = env(), env()
        # same arrays in both envs → draw once, copy
        e_hw["X"], e_hw["Y"] = e_sw["X"].copy(), e_sw["Y"].copy()
        evaluate(toy_domain._axpy_program(), e_sw)
        evaluate(res.program, e_hw,
                 intrinsics=lowering.registry.evaluators())
        np.testing.assert_allclose(e_sw["Oy"], e_hw["Oy"], atol=1e-12)

    def test_global_registry_untouched(self, lowering):
        """Isolated registries leave the process-wide one alone."""
        assert not default_registry().has_op("axpy")
        assert len(isax_library()) == 8


# ---------------------------------------------------------------------------
# (e) deprecation shims for the pre-registry entry points
# ---------------------------------------------------------------------------

class TestDeprecationShims:
    def test_dispatch_schedulers_kernels_views(self):
        from repro.compile import dispatch as D
        with pytest.warns(DeprecationWarning):
            scheds = D._SCHEDULERS
        with pytest.warns(DeprecationWarning):
            kerns = D._KERNELS
        reg = default_registry()
        assert set(scheds) == {op for op in reg.ops()
                               if reg.op_spec(op).scheduler is not None}
        assert kerns["flash_attention"] is reg.spec("flash_attention").kernel

    def test_offload_isax_library_shim(self):
        from repro.core import offload
        with pytest.warns(DeprecationWarning):
            lib = offload.isax_library()
        assert [i.name for i in lib] == [i.name for i in isax_library()]

    def test_offload_factory_reexports(self):
        from repro.core import offload
        with pytest.warns(DeprecationWarning, match="moved to"):
            assert offload.isax_rmsnorm().name == "rmsnorm"
        with pytest.warns(DeprecationWarning, match="moved to"):
            assert offload.isax_fps().name == "fps"
        with pytest.raises(AttributeError):
            offload.isax_nonexistent

    def test_top_level_lower_follows_default_dispatcher(self):
        """lower() with an explicit backend reuses the installed default
        policy's dispatcher — a custom registry set via
        set_default_lowering stays reachable (code-review regression)."""
        from repro.compile import lower, set_default_lowering
        reg = TargetRegistry()
        reg.register(llm_domain.DOMAIN)
        reg.register(toy_domain.DOMAIN)
        custom = LoweringConfig.from_registry("xla", registry=reg)
        prior = set_default_lowering(custom)
        try:
            rec = lower("axpy", shape=(16, 8), dtype="float32",
                        backend="pallas_interpret")
            assert rec.impl == "isax"
            assert rec.key in custom.dispatcher.records
        finally:
            set_default_lowering(prior)
