"""Sharding policy resolution (pure spec logic — no multi-device needed)."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import SHAPES, reduced
from repro.configs.registry import get_config
from repro.models.registry import cache_specs, get_model, input_specs
from repro.sharding.policies import (activation_specs, dp_axes,
                                     resolve_param_spec)


def _fake_mesh(shape, axes):
    """Mesh over a numpy device grid; spec resolution only reads sizes."""
    devs = np.array(jax.devices() * int(np.prod(shape)))[:int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


MESH = _fake_mesh((16, 16), ("data", "model"))
MESH3 = _fake_mesh((2, 16, 16), ("pod", "data", "model"))


class TestParamSpecs:
    def test_fsdp_tp_weight(self):
        spec = resolve_param_spec(("embed", "ff"), (4096, 12800), MESH)
        assert spec == P("data", "model")

    def test_vocab_table_is_vocab_parallel_only(self):
        """Embedding d_model axis must NOT shard over data (logit all-gather
        pathology, see policies docstring)."""
        spec = resolve_param_spec(("vocab", "embed"), (151936, 1024), MESH)
        assert spec == P("model", None)

    def test_indivisible_vocab_falls_back(self):
        spec = resolve_param_spec(("vocab", "embed"), (49155, 4096), MESH)
        assert spec == P(None, None)

    def test_gqa_kv_head_no_headdim_fallback_by_default(self):
        """head_dim TP is opt-in only: sharding the QK^T contraction dim
        makes every score tensor a partial-sum all-reduce (§Perf iter 2)."""
        spec = resolve_param_spec(("embed", "kv_heads", "head_dim"),
                                  (4096, 8, 128), MESH)
        assert spec == P("data", None, None)
        spec_hd = resolve_param_spec(("embed", "kv_heads", "head_dim"),
                                     (4096, 8, 128), MESH,
                                     policy="fsdp_tp_hd")
        assert spec_hd == P("data", None, "model")

    def test_no_double_use_of_axis(self):
        spec = resolve_param_spec(("ff", "embed"), (12800, 4096), MESH)
        # ff takes model, embed takes data — never the same axis twice
        assert spec[0] != spec[1]

    def test_layers_never_sharded(self):
        spec = resolve_param_spec(("layers", "embed", "ff"),
                                  (48, 4096, 12800), MESH)
        assert spec == P(None, "data", "model")


class TestActivationSpecs:
    def test_train_batch(self):
        cfg = get_config("granite-3-8b")
        specs = activation_specs(cfg, MESH, 256)
        assert specs["btd"] == P(("data",), None, None)

    def test_multipod_batch(self):
        cfg = get_config("granite-3-8b")
        specs = activation_specs(cfg, MESH3, 256)
        assert specs["btd"] == P(("pod", "data"), None, None)

    def test_batch_one_long_context(self):
        cfg = get_config("zamba2-1.2b")
        specs = activation_specs(cfg, MESH, 1)
        assert specs["btd"] is None  # batch 1 can't shard over data

    def test_moe_buffer_specs(self):
        cfg = get_config("arctic-480b")
        specs = activation_specs(cfg, MESH, 256)
        assert specs["ecd"] == P("model", "data", None)


class TestCacheSpecs:
    def test_kv_cache_specs_exist_for_all_decode_cells(self):
        from repro.sharding.policies import cache_shardings
        for arch in ("granite-3-8b", "mamba2-2.7b", "zamba2-1.2b",
                     "seamless-m4t-medium", "arctic-480b"):
            cfg = get_config(arch)
            specs = cache_specs(cfg, 128, 32768)
            sh = cache_shardings(cfg, MESH, specs)
            for leaf in jax.tree.leaves(
                    sh, is_leaf=lambda x: hasattr(x, "spec")):
                assert leaf.spec is not None

    def test_long_context_seq_parallel_kv(self):
        """batch-1 500k KV: sequence axis shards over 'data' (SP)."""
        from repro.sharding.policies import cache_shardings
        cfg = get_config("zamba2-1.2b")
        specs = cache_specs(cfg, 1, 524288)
        sh = cache_shardings(cfg, MESH, specs)
        assert sh["k"].spec == P(None, None, "data", "model", None)


class TestDryRunPlumbing:
    def test_input_specs_no_allocation(self):
        """input_specs must return ShapeDtypeStructs (zero allocation)."""
        cfg = get_config("arctic-480b")
        specs = input_specs(cfg, SHAPES["train_4k"])
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_collective_parser(self):
        from repro.roofline.analysis import parse_collectives
        hlo = """
ENTRY %main (p0: f32[16,4096]) -> f32[16,4096] {
  %ag = f32[256,4096]{1,0} all-gather(%p0), replica_groups=[16,16]<=[256]
  %ar = f32[16,4096]{1,0} all-reduce(%ag), replica_groups={{0,1,2,3}}
}
%region_5_spmd (x: f32[8]) -> f32[8] {
  %ar2 = f32[8]{0} all-reduce(%x), replica_groups=[16,16]<=[256]
}
"""
        st = parse_collectives(hlo, 256, loop_trip=10)
        assert st.counts["all-gather"] == 1
        assert st.counts["all-reduce"] == 2
        # in-loop op weighted ×10: 8 floats × 4B × 10 × ring factor present
        assert st.result_bytes["all-reduce"] >= 32 * 10

    def test_roofline_terms(self):
        from repro.roofline.analysis import roofline
        r = roofline(flops=1e18, hbm_bytes=1e15, wire_bytes_per_chip=1e9,
                     n_chips=256, model_flops=9e17)
        assert r.compute_s == pytest.approx(1e18 / (256 * 197e12))
        assert r.bottleneck == "compute"
        assert 0.8 < r.useful_ratio < 1.0
