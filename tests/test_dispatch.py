"""Compiler-driven kernel dispatch: e-graph lowering decisions, compile-cache
behavior, numerical parity of the matched-kernel path vs the XLA reference
across every registered model config, and the deprecation shim for the old
module-global impl flags."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compile import (LoweringConfig, Dispatcher, OpKey, TARGET_ISAX,
                           get_dispatcher, lower)
from repro.configs.base import reduced
from repro.configs.registry import available_configs, get_config
from repro.models.registry import get_model
from repro.serve.kv_cache import PagedKVCache

ARCHS = sorted(available_configs())
RNG = np.random.default_rng(0)


def _models(cfg, disp=None):
    disp = disp or Dispatcher()
    ref = get_model(cfg, lowering=LoweringConfig("xla", disp))
    isx = get_model(cfg, lowering=LoweringConfig("pallas_interpret", disp))
    return ref, isx


def _batch(cfg, B=2, S=16):
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family in ("vlm", "encdec"):
        batch["prefix_embeds"] = jnp.asarray(
            RNG.normal(size=(B, cfg.n_prefix_tokens, cfg.d_model)),
            jnp.float32)
    return batch


# ---------------------------------------------------------------------------
# (a) lowering decisions: what the e-graph pipeline matches and extracts
# ---------------------------------------------------------------------------

class TestLoweringDecisions:
    def test_attention_extracts_flash_isax(self):
        lw = LoweringConfig("pallas_interpret", Dispatcher())
        rec = lw.lower("attention", (1, 128, 4, 2, 128, 64), "float32")
        assert rec.impl == "isax"
        assert "flash_attention" in rec.matched
        assert rec.kernel_fn is not None
        assert rec.schedule["block_q"] >= 8

    def test_single_row_decode_falls_back(self):
        """The flash ISAX matched, but a 1-row query can't fill the
        row-blocked skeleton's tile — the compiler keeps the reference."""
        lw = LoweringConfig("pallas_interpret", Dispatcher())
        rec = lw.lower("attention_paged", (4, 1, 4, 2, 64, 16), "float32")
        assert rec.impl == "reference"
        assert "flash_attention" in rec.matched  # matched, not extracted
        assert "degenerate" in rec.note

    def test_plain_matmul_is_negative_control(self):
        """No bf16 GEMM ISAX exists: the plain matmul term must not match
        int8_matvec (whose component carries the quantization scale)."""
        lw = LoweringConfig("pallas_interpret", Dispatcher())
        rec = lw.lower("matmul", (32, 64, 128), "float32")
        assert rec.impl == "reference" and rec.matched == ()
        assert TARGET_ISAX["matmul"] is None

    def test_rmsnorm_ssd_int8_match(self):
        lw = LoweringConfig("pallas_interpret", Dispatcher())
        assert lw.lower("rmsnorm", (32, 64), "float32").impl == "isax"
        assert lw.lower("ssd_scan", (2, 16, 16, 8, 16),
                        "float32").impl == "isax"
        assert lw.lower("int8_matmul", (128, 128, 128),
                        "float32").impl == "isax"

    def test_xla_backend_records_match_but_runs_reference(self):
        lw = LoweringConfig("xla", Dispatcher())
        rec = lw.lower("attention", (1, 128, 4, 2, 128, 64), "float32")
        assert rec.impl == "reference"
        assert "flash_attention" in rec.matched

    def test_chunked_backend_for_attention(self):
        lw = LoweringConfig("xla_chunked", Dispatcher())
        rec = lw.lower("attention", (1, 128, 4, 2, 128, 64), "float32")
        assert rec.impl == "chunked"

    def test_unknown_op_rejected(self):
        """Op validation is a registry decision now (custom registries may
        know ops the global one does not), so the engine rejects at
        lowering time with the list of valid ops."""
        with pytest.raises(ValueError, match="known:"):
            Dispatcher().lower(OpKey("conv3d", (1,), "float32", "xla"))
        with pytest.raises(ValueError):
            OpKey("", (1,), "float32", "xla")

    def test_top_level_lower_entry_point(self):
        """repro.compile.lower is the public one-shot API over the shared
        process-wide cache."""
        rec = lower("rmsnorm", shape=(32, 64), dtype="float32",
                    backend="pallas_interpret")
        assert rec.impl == "isax"
        again = lower("rmsnorm", shape=(32, 64), dtype="float32",
                      backend="pallas_interpret")
        assert again is rec  # same CompileRecord from the shared cache


# ---------------------------------------------------------------------------
# (b) compile cache: persistent in-process, hit on the second lowering
# ---------------------------------------------------------------------------

class TestCompileCache:
    def test_cache_hit_on_second_lowering(self):
        disp = Dispatcher()
        lw = LoweringConfig("pallas_interpret", disp)
        key = ("attention", (1, 64, 4, 2, 64, 16), "float32")
        r1 = lw.lower(*key)
        assert disp.misses == 1 and disp.hits == 0
        r2 = lw.lower(*key)
        assert r2 is r1
        assert disp.hits == 1 and disp.misses == 1
        assert r1.hits == 1

    def test_second_trace_hits_cache(self):
        """Re-tracing the same model (same shapes) must not re-run the
        e-graph pipeline: every key resolves from the cache."""
        cfg = reduced(get_config("llama110m"))
        disp = Dispatcher()
        model = get_model(cfg, lowering=LoweringConfig("pallas_interpret",
                                                       disp))
        params = model.init(jax.random.key(0))
        batch = _batch(cfg)
        jax.eval_shape(lambda p, b: model.prefill(p, b, None), params, batch)
        misses0, hits0 = disp.misses, disp.hits
        assert misses0 > 0
        jax.eval_shape(lambda p, b: model.prefill(p, b, None), params, batch)
        assert disp.misses == misses0, "second trace recompiled"
        assert disp.hits > hits0

    def test_backend_is_part_of_the_key(self):
        disp = Dispatcher()
        shape = (1, 64, 4, 2, 64, 16)
        a = LoweringConfig("xla", disp).lower("attention", shape, "float32")
        b = LoweringConfig("pallas_interpret", disp).lower(
            "attention", shape, "float32")
        assert a.impl == "reference" and b.impl == "isax"
        assert disp.misses == 2

    def test_stats_shape(self):
        disp = Dispatcher()
        lw = LoweringConfig("pallas_interpret", disp)
        lw.lower("rmsnorm", (32, 64), "float32")
        lw.lower("matmul", (32, 64, 128), "float32")
        st = disp.stats()
        assert st["n_keys"] == 2 and st["matched_keys"] == 1
        assert 0.0 < st["match_rate"] < 1.0
        assert len(st["ops"]) == 2


# ---------------------------------------------------------------------------
# (c) numerical parity: matched-kernel lowering ≡ XLA reference for every
#     registered model config (prefill, static decode, paged decode)
# ---------------------------------------------------------------------------

TOL = dict(atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_dispatch_parity(arch):
    cfg = reduced(get_config(arch))
    ref, isx = _models(cfg)
    params = ref.init(jax.random.key(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)

    logits_ref, caches_ref = ref.prefill(params, batch, None)
    logits_isx, caches_isx = isx.prefill(params, batch, None)
    np.testing.assert_allclose(np.asarray(logits_ref),
                               np.asarray(logits_isx), **TOL,
                               err_msg=f"{arch}: prefill parity")

    tok = jnp.argmax(logits_ref, -1).astype(jnp.int32)
    for step in range(2):
        logits_ref, caches_ref = ref.decode_step(
            params, tok, caches_ref, jnp.int32(S + step))
        logits_isx, caches_isx = isx.decode_step(
            params, tok, caches_isx, jnp.int32(S + step))
        np.testing.assert_allclose(
            np.asarray(logits_ref), np.asarray(logits_isx), **TOL,
            err_msg=f"{arch}: static decode parity at step {step}")
        tok = jnp.argmax(logits_ref, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).family in ("dense", "moe")])
def test_dispatch_parity_paged_decode(arch):
    cfg = reduced(get_config(arch))
    ref, isx = _models(cfg)
    params = ref.init(jax.random.key(0))
    B, PL, MAXLEN, PS, GEN = 2, 16, 64, 16, 3
    prompts = np.asarray(RNG.integers(0, cfg.vocab, (B, PL)), np.int32)

    def run(model, token_stream=None):
        """token_stream None: greedy, recording fed tokens.  Otherwise replay
        the given stream so both lowerings see identical inputs (greedy
        argmax on near-tied logits would fork the comparison)."""
        cache = PagedKVCache(cfg, max_batch=B, page_size=PS,
                             n_pages=B * MAXLEN // PS, max_len=MAXLEN)
        toks = np.zeros((B,), np.int32)
        out, fed = [], []
        for b in range(B):
            cache.bind_slot(b, PL + GEN)
            lg, kv = model.prefill_at(
                params, {"tokens": jnp.asarray(prompts[b:b + 1])},
                jnp.int32(PL))
            cache.write_prefill(b, kv, PL)
            toks[b] = int(jnp.argmax(lg[0]))
        for step in range(GEN):
            if token_stream is not None:
                toks = token_stream[step]
            fed.append(toks.copy())
            pt, sl, act = cache.device_views(set(range(B)))
            lg, cache.k_pages, cache.v_pages = model.decode_paged(
                params, jnp.asarray(toks), cache.k_pages, cache.v_pages,
                pt, sl, act)
            cache.seq_lens[:] += 1
            toks = np.asarray(jnp.argmax(lg, -1), np.int32)
            out.append(np.asarray(lg))
        return out, fed

    ref_out, ref_fed = run(ref)
    isx_out, _ = run(isx, token_stream=ref_fed)
    for step, (a, b) in enumerate(zip(ref_out, isx_out)):
        np.testing.assert_allclose(
            a, b, **TOL,
            err_msg=f"{arch}: paged decode parity at step {step}")


# ---------------------------------------------------------------------------
# (d) standalone int8 matmul dispatch parity
# ---------------------------------------------------------------------------

def test_int8_matmul_dispatch_parity():
    from repro.kernels import ref as kref
    disp = Dispatcher()
    x = jnp.asarray(RNG.normal(size=(128, 128)), jnp.float32)
    wq = jnp.asarray(RNG.integers(-127, 127, size=(128, 128)), jnp.int8)
    scale = jnp.asarray(RNG.uniform(0.001, 0.02, size=(128,)), jnp.float32)
    got = LoweringConfig("pallas_interpret", disp).int8_matmul(x, wq, scale)
    want = kref.int8_matmul_ref(x, wq, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-2, rtol=2e-2)
    rec = disp.records[OpKey("int8_matmul", (128, 128, 128), "float32",
                             "pallas_interpret")]
    assert rec.impl == "isax"


# ---------------------------------------------------------------------------
# (e) env override + deprecation shim (the old module globals)
# ---------------------------------------------------------------------------

class TestConfigSurface:
    def test_env_override_read_in_constructor(self, monkeypatch):
        monkeypatch.setenv("REPRO_ATTENTION_IMPL", "xla_chunked")
        assert LoweringConfig().backend == "xla_chunked"
        monkeypatch.delenv("REPRO_ATTENTION_IMPL")
        monkeypatch.setenv("REPRO_BACKEND", "pallas_interpret")
        assert LoweringConfig().backend == "pallas_interpret"
        # explicit backend wins over the environment
        assert LoweringConfig("xla").backend == "xla"

    def test_invalid_backend_rejected(self):
        with pytest.raises(ValueError):
            LoweringConfig("cuda")

    def test_no_module_global_impl_flag_left(self):
        from repro.models import layers as L
        assert not hasattr(L, "_ATTENTION_IMPL")

    def test_set_attention_impl_shim(self):
        import repro.compile as C
        from repro.models import layers as L
        prior = C.get_default_backend()
        try:
            with pytest.warns(DeprecationWarning):
                L.set_attention_impl("xla_chunked")
            assert L.get_attention_impl() == "xla_chunked"
            assert C.get_default_backend() == "xla_chunked"
        finally:
            C.set_default_backend(prior)

    def test_default_dispatcher_is_process_wide(self):
        assert LoweringConfig("xla").dispatcher is get_dispatcher()
