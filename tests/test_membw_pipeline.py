"""Burst-DMA pipeline tests: interpret-mode numerical parity of the
pipelined kernels vs the unpipelined baselines (fp32/bf16/int8), the
synthesis buffer-depth decision under a constrained VMEM budget, and the
never-pipelined-on-a-predicted-loss guarantee."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernel_synth import (
    PIPELINE_GAIN_MIN,
    choose_flash_blocks,
    choose_matmul_blocks,
    choose_ssd_blocks,
)
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.pipeline import (
    flash_attention_pipelined,
    int8_matmul_pipelined,
    ssd_scan_pipelined,
    use_pipeline,
)
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(7)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------------------
# Numerical parity: pipelined vs unpipelined kernel bodies (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("depth", [2, 3])
def test_flash_pipelined_parity(dtype, depth):
    B, S, H, K, T, hd = 2, 128, 4, 2, 256, 64
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, T, K, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, T, K, hd)), dtype)
    mask = jnp.broadcast_to(jnp.tril(jnp.ones((S, T), bool), k=T - S)[None],
                            (1, S, T))
    got = flash_attention_pipelined(q, k, v, mask, sm_scale=hd ** -0.5,
                                    block_q=64, block_k=64, depth=depth,
                                    interpret=True)
    want = flash_attention(q, k, v, mask, sm_scale=hd ** -0.5,
                           block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("depth", [2, 4])
def test_int8_pipelined_parity(dtype, depth):
    """int8 weight tiles through the burst pipeline == BlockSpec staging."""
    M, N, K = 64, 128, 256
    x = jnp.asarray(RNG.normal(size=(M, K)), dtype)
    wq = jnp.asarray(RNG.integers(-127, 127, size=(N, K)), jnp.int8)
    sc = jnp.asarray(RNG.uniform(0.01, 0.02, size=(N,)), jnp.float32)
    got = int8_matmul_pipelined(x, wq, sc, block_m=32, block_n=64,
                                block_k=64, depth=depth, interpret=True)
    want = int8_matmul(x, wq, sc, block_m=32, block_n=64, block_k=64,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("depth", [2, 3])
def test_ssd_pipelined_parity(dtype, depth):
    BT, H, S, P, N = 2, 3, 128, 16, 8
    x = jnp.asarray(RNG.normal(size=(BT, H, S, P)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.1, size=(BT, H, S)), dtype)
    A = jnp.asarray(-RNG.uniform(0.5, 1.5, size=(H,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(BT, S, N)), dtype)
    C = jnp.asarray(RNG.normal(size=(BT, S, N)), dtype)
    got = ssd_scan_pipelined(x, dt, A, B, C, chunk=32, depth=depth,
                             interpret=True)
    want = ssd_scan(x, dt, A, B, C, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_ops_wrapper_pipeline_override_parity():
    """ops.* route both paths to the same numbers under explicit override."""
    B, S, H, K, T, hd = 1, 64, 2, 2, 512, 64
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, T, K, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, T, K, hd)), jnp.float32)
    mask = jnp.ones((1, S, T), bool)
    a = ops.flash_attention_gqa(q, k, v, mask, sm_scale=hd ** -0.5,
                                interpret=True, pipelined=True)
    b = ops.flash_attention_gqa(q, k, v, mask, sm_scale=hd ** -0.5,
                                interpret=True, pipelined=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    want = ref.flash_attention_ref(q, k, v, mask, sm_scale=hd ** -0.5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(want), atol=2e-5,
                               rtol=2e-4)


# ---------------------------------------------------------------------------
# Synthesis decision: buffer depth under a VMEM budget, loss veto
# ---------------------------------------------------------------------------

def test_buffer_depth_shrinks_under_vmem_pressure():
    """The synthesized depth must respect the VMEM budget: a tight budget
    prices deep staging out (collapsing to the BlockSpec baseline, since a
    depth-2 explicit pipeline never beats Mosaic's implicit double
    buffering), an impossible one raises."""
    full = choose_flash_blocks(64, 4096, 64, dtype_bytes=2)
    assert full.buffering > 2 and full.pipelined
    # ~300 KiB: deep staging shaved but still worth pipelining
    mid = choose_flash_blocks(64, 4096, 64, dtype_bytes=2,
                              vmem_budget=300 * 1024)
    assert mid.vmem_bytes <= 300 * 1024
    assert mid.buffering < full.buffering and mid.pipelined
    # ~250 KiB: only the (implicitly double-buffered) baseline fits
    tight = choose_flash_blocks(64, 4096, 64, dtype_bytes=2,
                                vmem_budget=250 * 1024)
    assert tight.vmem_bytes <= 250 * 1024
    assert tight.buffering == 1 and not tight.pipelined
    with pytest.raises(AssertionError):
        choose_flash_blocks(64, 4096, 64, dtype_bytes=2,
                            vmem_budget=32 * 1024)


def test_matmul_depth_under_vmem_pressure():
    """Memory-bound skinny GEMM: the budget constrains the working set, and
    the synthesizer pays for it in predicted cycles (smaller tiles / fewer
    buffers), down to infeasibility."""
    full = choose_matmul_blocks(8, 4096, 8192, dtype_bytes=1)
    assert full.buffering > 2 and full.pipelined
    tight_budget = 512 * 1024
    tight = choose_matmul_blocks(8, 4096, 8192, dtype_bytes=1,
                                 vmem_budget=tight_budget)
    assert tight.vmem_bytes <= tight_budget < full.vmem_bytes
    assert tight.est_total_cycles >= full.est_total_cycles
    with pytest.raises(AssertionError):
        choose_matmul_blocks(8, 4096, 8192, dtype_bytes=1,
                             vmem_budget=8 * 1024)


def test_pipeline_never_selected_on_predicted_loss():
    """A single streamed tile can't overlap; a compute-bound GEMM gains
    nothing over BlockSpec's implicit double buffering — neither may select
    the burst pipeline, and every pipelined schedule must carry a predicted
    gain above the threshold."""
    degenerate = choose_flash_blocks(64, 64, 64)
    assert not degenerate.pipelined
    assert degenerate.buffering == 1
    assert degenerate.decisions["pipeline"] == "off"
    fat_gemm = choose_matmul_blocks(4096, 4096, 4096)
    assert not fat_gemm.pipelined  # compute-bound: implicit overlap suffices
    for sched in (choose_flash_blocks(64, 4096, 64),
                  choose_matmul_blocks(8, 4096, 8192, dtype_bytes=1),
                  choose_ssd_blocks(4096, 80, 64, 128)):
        assert sched.pipelined  # memory-bound: deep staging predicted to win
        assert sched.pipeline_gain >= PIPELINE_GAIN_MIN
        assert sched.est_total_cycles <= sched.est_serial_cycles


def test_ops_wrappers_honor_synthesis_decision():
    """With one streamed tile the wrapper must not pipeline even when the
    caller forces it (nothing to overlap)."""
    sched = choose_flash_blocks(64, 64, 64)
    assert use_pipeline(sched, None, 1) is False
    assert use_pipeline(sched, True, 1) is False
    assert use_pipeline(sched, True, 4) is True
    assert use_pipeline(sched, False, 4) is False
    rich = choose_flash_blocks(1024, 4096, 128)
    assert use_pipeline(rich, None, 32) == rich.pipelined


def test_dispatch_records_pipeline_decision():
    """The compile-cache entry exposes the burst-DMA decision (surfaced by
    bench_compile_stats into BENCH_compile.json)."""
    from repro.compile import Dispatcher, OpKey
    disp = Dispatcher()
    rec = disp.lower(OpKey("attention", (1, 128, 4, 4, 2048, 64),
                           "float32", "pallas_interpret"))
    assert rec.impl == "isax"
    for field in ("pipelined", "buffering", "pipeline_gain",
                  "est_serial_cycles"):
        assert field in rec.schedule
    st = disp.stats()
    assert st["pipelined_keys"] == int(bool(rec.schedule["pipelined"]))
