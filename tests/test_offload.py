"""Retargetable-compiler robustness (paper §5, Table 3, §6.2 "Compiler
Support"): syntactic variants must still match, offloaded programs must be
numerically identical, and e-graph sizes must stay bounded."""

import numpy as np
import pytest

from repro.core import expr
from repro.core.expr import arr, const, for_, var
from repro.core.matching import decompose
from repro.core.offload import compile_program, evaluate
from repro.targets import isax_library
from repro.targets.llm import (
    isax_flash_attention,
    isax_int8_matvec,
    isax_rmsnorm,
    isax_ssd_step,
)
from repro.kernels.ops import register_kernel_intrinsics

register_kernel_intrinsics()  # offloaded programs run the Pallas datapaths


def _run_both(sw, result, env_fn, outs, atol=1e-5):
    e0, e1 = env_fn(), env_fn()
    evaluate(sw, e0)
    evaluate(result.program, e1)
    for o in outs:
        np.testing.assert_allclose(e0[o], e1[o], atol=atol, rtol=1e-4)


def _mv_body(iexpr):
    return ("store", arr("C"), iexpr,
            ("*", var("s_w"), ("matvec", arr("Wq"), ("load", arr("X"),
                                                     iexpr))))


def _mv_env(n=8, m=5, k2=7, seed=1):
    rng = np.random.default_rng(seed)
    return dict(Wq=rng.integers(-127, 127, size=(m, k2)).astype(np.int8),
                X=rng.normal(size=(n, k2)), s_w=0.02, n=n,
                C=np.zeros((n, m)))


class TestInt8Matvec:
    def test_exact_match(self):
        sw = isax_int8_matvec().term
        res = compile_program(sw, [isax_int8_matvec()], case="exact")
        assert res.stats.matched_isaxes == ["int8_matvec"]

    def test_unrolled_variant(self):
        """Paper Table 3 'Unroll(2/4)' row: re-rolling via external rewrite."""
        sw = for_("i", const(0), const(8), const(2),
                  _mv_body(var("i")), _mv_body(("+", var("i"), const(1))))
        res = compile_program(sw, [isax_int8_matvec()], case="unrolled")
        assert "int8_matvec" in res.stats.matched_isaxes
        assert res.stats.external_rewrites >= 1
        _run_both(sw, res, _mv_env, ["C"])

    def test_tiled_variant(self):
        """Paper Table 3 'Tiling(4)' row: coalescing via external rewrite."""
        inner = for_("j", var("it"), ("+", var("it"), const(4)), const(1),
                     _mv_body(var("j")))
        sw = for_("it", const(0), const(8), const(4), inner)
        res = compile_program(sw, [isax_int8_matvec()], case="tiled")
        assert "int8_matvec" in res.stats.matched_isaxes
        _run_both(sw, res, _mv_env, ["C"])

    def test_shifted_index_variant(self):
        """Non-affine i<<0-style arithmetic in the body is normalized by
        internal rewrites (the paper's i≪2 ↦ i*4 example)."""
        body = ("store", arr("C"), var("i"),
                ("*", var("s_w"),
                 ("matvec", arr("Wq"),
                  ("load", arr("X"), (">>", ("<<", var("i"), const(1)),
                                      const(1))))))
        sw = for_("i", const(0), var("n"), const(1), body)
        res = compile_program(sw, [isax_int8_matvec()], case="shifted")
        assert "int8_matvec" in res.stats.matched_isaxes

    def test_scale_position_variant(self):
        """Scale applied inside the matvec operand instead of outside."""
        body = ("store", arr("C"), var("i"),
                ("matvec", arr("Wq"),
                 ("*", var("s_w"), ("load", arr("X"), var("i")))))
        sw = for_("i", const(0), var("n"), const(1), body)
        res = compile_program(sw, [isax_int8_matvec()], case="scale-moved")
        assert "int8_matvec" in res.stats.matched_isaxes

    def test_non_matching_program_is_untouched(self):
        """A semantically different loop (extra accumulation) must NOT match."""
        body = ("store", arr("C"), var("i"),
                ("+", ("load", arr("C"), var("i")),
                 ("*", var("s_w"), ("matvec", arr("Wq"),
                                    ("load", arr("X"), var("i"))))))
        sw = for_("i", const(0), var("n"), const(1), body)
        res = compile_program(sw, [isax_int8_matvec()], case="negative")
        assert "int8_matvec" not in res.stats.matched_isaxes


class TestFlashAttention:
    def _sw_noshift(self):
        i = var("i")
        q = ("load", arr("Q"), i)
        s = ("/", ("exp", ("matvec", arr("K"), ("*", var("scale"), q))),
             ("rowsum", ("exp", ("matvec", arr("K"),
                                 ("*", var("scale"), q)))))
        return for_("i", const(0), var("n_q"), const(1),
                    ("store", arr("P"), i, s),
                    ("store", arr("O"), i,
                     ("matvec", ("transpose", arr("V")),
                      ("load", arr("P"), i))))

    def _env(self, seed=0):
        rng = np.random.default_rng(seed)
        nq, nk, d = 4, 6, 8
        return dict(Q=rng.normal(size=(nq, d)), K=rng.normal(size=(nk, d)),
                    V=rng.normal(size=(nk, d)), scale=0.3, n_q=nq,
                    P=np.zeros((nq, nk)), O=np.zeros((nq, d)))

    def test_softmax_shift_and_scale_variants_match(self):
        """No-max-shift softmax + scale-on-q: two simultaneous algebraic
        divergences (the paper's AF+RF composition)."""
        sw = self._sw_noshift()
        res = compile_program(sw, [isax_flash_attention()], case="attn")
        assert res.stats.matched_isaxes == ["flash_attention"]
        _run_both(sw, res, self._env, ["O", "P"])

    def test_offloaded_runs_pallas_kernel(self):
        sw = self._sw_noshift()
        res = compile_program(sw, [isax_flash_attention()], case="attn2")
        assert expr.op(res.program).startswith("isax:")


class TestSSD:
    def test_loop_carried_dependence_matches(self):
        """The H-state accumulator exercises the §5.4 loop-carried check."""
        ix = isax_ssd_step()
        res = compile_program(ix.term, [ix], case="ssd")
        assert res.stats.matched_isaxes == ["ssd_step"]

    def test_ssd_numerics(self):
        ix = isax_ssd_step()
        res = compile_program(ix.term, [ix], case="ssd-n")

        def env():
            rng = np.random.default_rng(3)
            T, n, p = 5, 4, 3
            return dict(A=rng.uniform(0.2, 0.9, size=(T,)),
                        B=rng.normal(size=(T, n)), C=rng.normal(size=(T, n)),
                        X=rng.normal(size=(T, p)), T=T,
                        H=np.zeros((1, n, p)), Y=np.zeros((T, n)))

        # note: Y[t] = H^T C_t has shape (p,) — fix Y buffer accordingly
        def env2():
            e = env()
            e["Y"] = np.zeros((e["T"], e["X"].shape[1]))
            return e

        _run_both(ix.term, res, env2, ["Y", "H"])


class TestRMSNorm:
    def test_match_and_numerics(self):
        ix = isax_rmsnorm()
        res = compile_program(ix.term, [ix], case="rms")
        assert res.stats.matched_isaxes == ["rmsnorm"]

        def env():
            rng = np.random.default_rng(4)
            n, d = 6, 16
            return dict(Xn=rng.normal(size=(n, d)), G=rng.normal(size=(d,)),
                        eps=1e-6, n=n, On=np.zeros((n, d)))

        _run_both(ix.term, res, env, ["On"])


class TestSwiGLU:
    def test_sigmoid_form_variants_match(self):
        """silu spelled x/(1+e^-x) vs x·recip(1+e^-x) — both offload."""
        from repro.targets.llm import isax_swiglu
        from repro.core.expr import arr, const, for_, var
        ix = isax_swiglu()
        i = var("i")
        x = ("load", arr("Xs"), i)
        g = ("matvec", arr("Wg"), x)
        u = ("matvec", arr("Wu"), x)
        silu2 = ("*", g, ("recip", ("+", ("const:1",), ("exp", ("neg", g)))))
        sw = for_("i", const(0), var("n"), const(1),
                  ("store", arr("Os"), i,
                   ("matvec", ("transpose", arr("Wo")), ("*", silu2, u))))
        res = compile_program(sw, [ix], case="swiglu-recip")
        assert res.stats.matched_isaxes == ["swiglu"]

        def env():
            r = np.random.default_rng(0)
            d, ff, n = 8, 12, 4
            return dict(Wg=r.normal(size=(ff, d)), Wu=r.normal(size=(ff, d)),
                        Wo=r.normal(size=(ff, d)), Xs=r.normal(size=(n, d)),
                        n=n, Os=np.zeros((n, d)))

        _run_both(sw, res, env, ["Os"])


class TestCompileStats:
    def test_table3_shape(self):
        """Stats mirror Table 3: saturated ≥ initial e-nodes, counts logged."""
        sw = for_("i", const(0), const(8), const(2),
                  _mv_body(var("i")), _mv_body(("+", var("i"), const(1))))
        res = compile_program(sw, [isax_int8_matvec()], case="stats")
        s = res.stats
        assert s.saturated_enodes >= s.initial_enodes > 0
        assert s.internal_rewrites > 0
        assert s.saturated_enodes < 60_000  # ISAX-guided pruning holds

    def test_multi_isax_library(self):
        """Full library tagging on one program doesn't cross-fire."""
        sw = isax_rmsnorm().term
        res = compile_program(sw, isax_library(), case="library")
        assert res.stats.matched_isaxes == ["rmsnorm"]


class TestDecompose:
    def test_skeleton_components_shapes(self):
        skel = decompose(isax_flash_attention())
        assert len(skel.components) == 2          # Figure 5: two components
        assert expr.op(skel.pattern).startswith("for:")
        assert skel.loop_struct is not None

    def test_self_dependence_detected(self):
        skel = decompose(isax_ssd_step())
        deps = [c.self_dep_array for c in skel.components]
        assert "H" in deps                        # loop-carried accumulator
