import os
import sys

# src/ layout import path (so plain `pytest tests/` works too)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no --xla_force_host_platform_device_count here — smoke tests must see
# the real single CPU device; only launch/dryrun.py forces 512.
