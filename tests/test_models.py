"""Per-architecture smoke tests (reduced configs of the same family) plus
model-level correctness: SSD math, prefill→decode continuity, MoE routing,
param-axes tree consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, applicable_shapes, reduced
from repro.configs.registry import ASSIGNED_ARCHS, all_configs, get_config
from repro.models.registry import get_model, input_specs, param_specs

ARCHS = list(all_configs().keys())


def _batch_for(cfg, B=2, S=16, with_labels=True, key=0):
    rng = jax.random.key(key)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    out = {"tokens": toks}
    if with_labels:
        out["labels"] = toks
    if cfg.family == "vlm":
        out["prefix_embeds"] = jnp.ones((B, cfg.n_prefix_tokens, cfg.d_model))
    if cfg.family == "encdec":
        out["prefix_embeds"] = jnp.ones((B, 8, cfg.d_model))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_train_step(arch):
    """Reduced config: one forward + one train step, finite outputs."""
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch_for(cfg)
    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(model.loss)(params, batch)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = _batch_for(cfg, B, S, with_labels=False)
    npref = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    logits, caches = model.prefill(params, batch, S + npref + 4)
    assert logits.shape == (B, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lg, caches2 = model.decode_step(params, tok, caches,
                                    jnp.int32(S + npref))
    assert lg.shape == (B, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg)))


@pytest.mark.parametrize("arch", ["granite-3-8b", "qwen1.5-0.5b",
                                  "mamba2-2.7b", "zamba2-1.2b",
                                  "paligemma-3b", "dbrx-132b",
                                  "seamless-m4t-medium"])
def test_decode_matches_prefill(arch):
    """decode_step(t|prefix) must equal prefill(prefix+t) logits."""
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(2), (B, S + 1), 0, cfg.vocab)
    npref = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    batch = _batch_for(cfg, B, S, with_labels=False, key=3)
    batch["tokens"] = toks[:, :S]
    _, caches = model.prefill(params, batch, S + npref + 4)
    lg, _ = model.decode_step(params, toks[:, S], caches,
                              jnp.int32(S + npref))
    batch2 = dict(batch, tokens=toks[:, :S + 1])
    lg_want, _ = model.prefill(params, batch2, S + npref + 4)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_want),
                               atol=5e-5, rtol=1e-4)


def test_ssd_chunked_equals_naive():
    from repro.models.mamba2 import ssd_chunked
    rng = np.random.default_rng(0)
    b, s, H, P, N = 2, 48, 3, 4, 5  # 48 not divisible by chunk 16 → padding
    x = jnp.asarray(rng.normal(size=(b, s, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, size=(b, s, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, size=(H,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, N)), jnp.float32)
    y = ssd_chunked(x, dt, A, B, C, chunk=16)
    h = np.zeros((b, H, N, P))
    ys = []
    for t in range(s):
        dec = np.exp(np.asarray(dt[:, t]) * np.asarray(A))
        h = dec[:, :, None, None] * h + np.einsum(
            "bh,bn,bhp->bhnp", np.asarray(dt[:, t]), np.asarray(B[:, t]),
            np.asarray(x[:, t]))
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(C[:, t]), h))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), atol=1e-4)


def test_moe_routing_capacity_and_balance():
    from repro.configs.base import MoEConfig
    from repro.models.moe import init_moe, moe_mlp
    import dataclasses
    cfg = reduced(get_config("dbrx-132b"))
    params = init_moe(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y, aux = moe_mlp(params, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(aux)) and float(aux) > 0
    # loss-free small-T capacity: all tokens routed (no silent drops)
    y2, _ = moe_mlp(params, x * 2, cfg)
    assert not bool(jnp.allclose(y, y2))


def test_param_axes_tree_matches_params():
    """The logical-axes tree must mirror the param tree leaf-for-leaf with
    matching ranks — this is what sharding resolution relies on."""
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        model = get_model(cfg)
        params = jax.eval_shape(model.init, jax.random.key(0))
        axes = model.param_axes()
        jax.tree.map(
            lambda a, p: None if len(a) == len(p.shape) else
            pytest.fail(f"{arch}: axes {a} vs shape {p.shape}"),
            axes, params,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))


def test_input_specs_cover_all_cells():
    """input_specs yields well-formed ShapeDtypeStructs for every assigned
    (arch × applicable shape) — 40 cells minus documented skips."""
    n = 0
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            specs = input_specs(cfg, shape)
            for leaf in jax.tree.leaves(specs):
                assert all(d > 0 for d in leaf.shape)
            n += 1
    # 10 archs × 4 shapes = 40 assigned cells; long_500k is skipped for the
    # 8 pure full-attention archs (DESIGN.md §4) → 32 runnable cells.
    assert n == 32
